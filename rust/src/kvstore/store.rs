//! The tiered, block-granular KV store.
//!
//! [`KvStore`] tracks, for every admitted sequence (decode group), where
//! each of its fixed-size token blocks lives — gpu-hbm, pinned or cpu-dram
//! — with one byte-accounted reservation per block.  On top of placement it
//! implements the three policy levers of the subsystem:
//!
//! * **Promotion** ([`KvStore::begin_promotions`] /
//!   [`KvStore::complete_landed`]): pull a sequence's blocks up into the
//!   gpu tier ahead of its next decode step, asynchronously over the
//!   migration link.  Resident blocks form a *suffix* of the valid tokens
//!   (the newest KV), so every step's H2D transfer shrinks by the resident
//!   length — the "already-on-GPU blocks shrink the transfer term" input to
//!   [`Planner::plan_batch_tiered`](crate::scheduler::Planner::plan_batch_tiered).
//! * **Eviction**: when the gpu tier is full, the configured
//!   [`EvictPolicy`](super::EvictPolicy) picks a victim among the *lowest*
//!   blocks of other sequences' resident runs (so residency stays a
//!   suffix) and it is demoted one tier down.
//! * **Recompute-aware reclamation** ([`KvStore::admit`] internally):
//!   admission that would otherwise backpressure may instead *drop the KV
//!   and keep the X activations* of prefix blocks — the Eq. (11) insight
//!   turned into a capacity lever: those tokens are rebuilt by the
//!   recompute path, so their stored KV was dead weight.  The dropped
//!   prefix becomes a planner floor (`l ≥ dropped`), reported by
//!   [`KvStore::kv_dropped_tokens`].

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::transfer::{LinkConfig, Priority};

use super::block::{BlockId, Tier};
use super::manager::{PendingMigration, TierManager, TierStats};
use super::policy::{BlockView, EvictPolicy};

/// Construction parameters for a [`KvStore`].
#[derive(Debug, Clone)]
pub struct KvStoreConfig {
    /// gpu-hbm tier capacity — the KV-dedicated slice of device memory.
    pub gpu_bytes: u64,
    /// Pinned host tier capacity (also backs migration staging buffers).
    pub pinned_bytes: u64,
    /// Cold cpu-dram tier capacity.
    pub dram_bytes: u64,
    /// Tokens per block.  Match the smallest artifact L bucket so dropped-KV
    /// floors land on a real recompute bucket.
    pub block_tokens: usize,
    /// Migration link shaping (PCIe-ish for promotions).
    pub link: LinkConfig,
}

impl KvStoreConfig {
    pub fn new(gpu_bytes: u64) -> Self {
        KvStoreConfig {
            gpu_bytes,
            pinned_bytes: 64 << 20,
            dram_bytes: 256 << 20,
            block_tokens: 32,
            link: LinkConfig::with_bandwidth(30e6),
        }
    }
}

/// One block's placement state.
struct BlockState {
    tier: Tier,
    /// The tier reservation; `None` only transiently mid-swap.
    guard: Option<crate::memory::PoolGuard>,
    /// KV bytes dropped (X kept): the block costs ⅓ and must be covered by
    /// the recompute path when its tokens are needed.
    kv_dropped: bool,
    /// In-flight promotion, if any.
    pending: Option<PendingMigration>,
}

/// Per-sequence bookkeeping.
struct SeqEntry {
    blocks: Vec<BlockState>,
    block_bytes: u64,
    /// Valid cached tokens (the paper's s'); grows as decode proceeds.
    tokens: usize,
    /// Latest planner split l* for this sequence (eviction scoring input).
    split_l: usize,
    last_use: u64,
}

/// Aggregate store counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub admitted: u64,
    pub promotions_started: u64,
    pub promotions_landed: u64,
    pub demotions: u64,
    pub kv_drops: u64,
    /// Landed promotions discarded because an eviction broke the resident
    /// suffix over them while they were in flight.
    pub promotions_wasted: u64,
    /// Top blocks flipped to gpu without link traffic (their KV was
    /// produced on-device by the decode step itself).
    pub device_syncs: u64,
}

/// The tiered block-granular KV store.
pub struct KvStore {
    mgr: TierManager,
    policy: Box<dyn EvictPolicy>,
    seqs: BTreeMap<u64, SeqEntry>,
    block_tokens: usize,
    clock: u64,
    stats: StoreStats,
}

impl KvStore {
    pub fn new(cfg: KvStoreConfig, policy: Box<dyn EvictPolicy>) -> Self {
        assert!(cfg.block_tokens > 0, "block_tokens must be positive");
        KvStore {
            mgr: TierManager::new(cfg.gpu_bytes, cfg.pinned_bytes, cfg.dram_bytes, cfg.link),
            policy,
            seqs: BTreeMap::new(),
            block_tokens: cfg.block_tokens,
            clock: 0,
            stats: StoreStats::default(),
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn stats(&self) -> StoreStats {
        self.stats
    }

    pub fn tier_stats(&self) -> TierStats {
        self.mgr.stats()
    }

    /// Bytes currently reserved in `tier`.
    pub fn tier_used(&self, tier: Tier) -> u64 {
        self.mgr.pool(tier).used()
    }

    fn valid_blocks_of(e: &SeqEntry, bt: usize) -> usize {
        e.tokens.div_ceil(bt).min(e.blocks.len())
    }

    fn block_tokens_at(e: &SeqEntry, idx: usize, bt: usize) -> usize {
        e.tokens.saturating_sub(idx * bt).min(bt)
    }

    /// Admit a sequence whose full-capacity cache is `total_bytes` split
    /// into `n_blocks` blocks.  Blocks are placed cold-first in the *host*
    /// tiers only (dram, then pinned) — the gpu tier is a cache layer
    /// filled exclusively by promotion/sync, so its capacity can never be
    /// parked under not-yet-valid admission blocks that eviction (which
    /// only walks resident suffix runs) could not reclaim.  When the host
    /// tiers are full the store reclaims by dropping droppable KV prefixes
    /// before giving up.  On failure all partial reservations roll back
    /// and the caller backpressures.
    pub fn admit(&mut self, seq: u64, total_bytes: u64, n_blocks: usize) -> Result<()> {
        if self.seqs.contains_key(&seq) {
            bail!("sequence {seq} already admitted");
        }
        if n_blocks == 0 {
            bail!("admit with zero blocks");
        }
        let block_bytes = total_bytes.div_ceil(n_blocks as u64);
        // feasibility pre-check, side-effect free: a hopeless admission
        // must not drain other sequences' droppable KV (the serving loop
        // retries every step, so leaked drops would compound into planner
        // floors for every running group)
        let free = self.mgr.pool(Tier::CpuDram).available()
            + self.mgr.pool(Tier::Pinned).available();
        if free + self.reclaimable_bytes() < block_bytes * n_blocks as u64 {
            bail!(
                "kvstore cannot fit sequence {seq}: {} bytes needed, {} free + reclaimable",
                block_bytes * n_blocks as u64,
                free + self.reclaimable_bytes()
            );
        }
        let mut blocks = Vec::with_capacity(n_blocks);
        for _ in 0..n_blocks {
            let placed = loop {
                if let Some(g) = self.mgr.grab(Tier::CpuDram, block_bytes) {
                    break Some((Tier::CpuDram, g));
                }
                if let Some(g) = self.mgr.grab(Tier::Pinned, block_bytes) {
                    break Some((Tier::Pinned, g));
                }
                if self.reclaim_kv_one().is_none() {
                    break None;
                }
            };
            match placed {
                Some((tier, guard)) => blocks.push(BlockState {
                    tier,
                    guard: Some(guard),
                    kv_dropped: false,
                    pending: None,
                }),
                None => {
                    // `blocks` drops here, rolling the reservations back
                    bail!(
                        "kvstore exhausted admitting sequence {seq}: placed {} of {n_blocks} blocks",
                        blocks.len()
                    );
                }
            }
        }
        self.clock += 1;
        self.seqs.insert(
            seq,
            SeqEntry { blocks, block_bytes, tokens: 0, split_l: 0, last_use: self.clock },
        );
        self.stats.admitted += 1;
        Ok(())
    }

    /// Retire a sequence, releasing every reservation.  In-flight
    /// promotions are *completed* (blocking briefly on the link) rather
    /// than dropped, so their staging buffers return to the pinned pool
    /// instead of stranding phantom pinned charges.
    pub fn release(&mut self, seq: u64) {
        if let Some(e) = self.seqs.remove(&seq) {
            for b in e.blocks {
                if let Some(pm) = b.pending {
                    let _ = self.mgr.finish_migration(pm);
                }
            }
        }
    }

    /// Record a decode step: current cached length and the planner's split.
    pub fn touch(&mut self, seq: u64, tokens: usize, split_l: usize) {
        self.clock += 1;
        if let Some(e) = self.seqs.get_mut(&seq) {
            e.tokens = e.tokens.max(tokens);
            e.split_l = split_l;
            e.last_use = self.clock;
        }
    }

    /// Tokens of the sequence's *resident suffix*: the run of settled
    /// gpu-tier blocks ending at the newest valid token.
    pub fn gpu_resident_tokens(&self, seq: u64) -> usize {
        let bt = self.block_tokens;
        let Some(e) = self.seqs.get(&seq) else { return 0 };
        let mut covered = 0;
        let mut idx = Self::valid_blocks_of(e, bt);
        while idx > 0 {
            idx -= 1;
            let b = &e.blocks[idx];
            if b.tier == Tier::GpuHbm && b.pending.is_none() && !b.kv_dropped {
                covered += Self::block_tokens_at(e, idx, bt);
            } else {
                break;
            }
        }
        covered
    }

    /// Length of the contiguous dropped-KV prefix — the planner's `l` floor.
    pub fn kv_dropped_tokens(&self, seq: u64) -> usize {
        let Some(e) = self.seqs.get(&seq) else { return 0 };
        e.blocks.iter().take_while(|b| b.kv_dropped).count() * self.block_tokens
    }

    /// In-flight promotions across all sequences.
    pub fn pending_count(&self) -> usize {
        self.seqs
            .values()
            .map(|e| e.blocks.iter().filter(|b| b.pending.is_some()).count())
            .sum()
    }

    /// The engine keeps the newest `engine_resident` tokens on device for
    /// free (their K/V was just computed there); mirror that into the gpu
    /// tier's accounting where the budget allows — no link traffic — and
    /// return the store-backed resident token count.  When the gpu tier
    /// cannot back the engine's window, the returned count is smaller and
    /// the caller demotes the engine window to match (budget enforcement).
    pub fn sync_device_suffix(&mut self, seq: u64, engine_resident: usize) -> usize {
        let bt = self.block_tokens;
        let todo: Vec<usize> = {
            let Some(e) = self.seqs.get(&seq) else { return 0 };
            let mut todo = Vec::new();
            let mut covered = 0usize;
            let mut idx = Self::valid_blocks_of(e, bt);
            while idx > 0 && covered < engine_resident {
                idx -= 1;
                let b = &e.blocks[idx];
                covered += Self::block_tokens_at(e, idx, bt);
                if b.pending.is_some() {
                    break; // a promotion is already bringing this one up
                }
                if b.tier != Tier::GpuHbm && !b.kv_dropped {
                    todo.push(idx);
                }
            }
            todo
        };
        let Some(block_bytes) = self.seqs.get(&seq).map(|e| e.block_bytes) else { return 0 };
        for idx in todo {
            let Some(guard) = self.mgr.grab(Tier::GpuHbm, block_bytes) else { break };
            let Some(e) = self.seqs.get_mut(&seq) else { break };
            let b = &mut e.blocks[idx];
            b.guard = Some(guard); // old tier reservation released
            b.tier = Tier::GpuHbm;
            self.stats.device_syncs += 1;
        }
        self.gpu_resident_tokens(seq)
    }

    /// Start up to `max_blocks` asynchronous promotions extending `seq`'s
    /// resident suffix downward (prefetch ahead of its decode step).  When
    /// the gpu tier is full, the eviction policy demotes other sequences'
    /// run-start blocks to make room.  Returns promotions issued.
    pub fn begin_promotions(&mut self, seq: u64, max_blocks: usize) -> usize {
        let bt = self.block_tokens;
        let (targets, block_bytes) = {
            let Some(e) = self.seqs.get(&seq) else { return 0 };
            let mut targets = Vec::new();
            let mut idx = Self::valid_blocks_of(e, bt);
            while idx > 0 && targets.len() < max_blocks {
                idx -= 1;
                let b = &e.blocks[idx];
                if let Some(pm) = &b.pending {
                    if pm.to() == Tier::GpuHbm {
                        continue; // already on its way up
                    }
                    break;
                }
                if b.tier == Tier::GpuHbm {
                    continue; // part of the established run
                }
                if b.kv_dropped {
                    break; // nothing to promote below a dropped prefix
                }
                targets.push(idx);
            }
            (targets, e.block_bytes)
        };
        let mut issued = 0;
        'targets: for idx in targets {
            // evict until the block fits: victims' blocks may be smaller
            // than ours (different batch buckets), so one demotion is not
            // always enough; the loop is bounded by the candidate supply
            let pm = loop {
                if let Some(pm) =
                    self.mgr.begin_migration(Tier::GpuHbm, block_bytes, Priority::High)
                {
                    break pm;
                }
                if !self.evict_gpu_victim(seq) {
                    break 'targets;
                }
            };
            let Some(e) = self.seqs.get_mut(&seq) else { break };
            e.blocks[idx].pending = Some(pm);
            self.stats.promotions_started += 1;
            issued += 1;
        }
        issued
    }

    /// Complete every landed promotion (non-blocking); returns how many
    /// were installed.  A landed block is only installed into the gpu tier
    /// while it still extends the resident suffix from above — if an
    /// eviction opened a hole over it in the meantime, installing would
    /// strand gpu bytes no eviction walk can ever reach, so the new
    /// reservation is dropped and the block stays where it was.
    pub fn complete_landed(&mut self) -> usize {
        let Self { mgr, seqs, stats, block_tokens, .. } = self;
        let bt = *block_tokens;
        let mut landed = 0;
        for e in seqs.values_mut() {
            // walk top-down so an upper block landing this pass extends
            // the run before the one below it is judged
            let mut suffix_ok = true;
            let mut idx = Self::valid_blocks_of(e, bt);
            while idx > 0 {
                idx -= 1;
                if e.blocks[idx].pending.as_ref().is_some_and(|pm| pm.is_done()) {
                    let pm = e.blocks[idx].pending.take().unwrap();
                    let (tier, guard) = mgr.finish_migration(pm);
                    if suffix_ok {
                        let b = &mut e.blocks[idx];
                        b.guard = Some(guard);
                        b.tier = tier;
                        stats.promotions_landed += 1;
                        landed += 1;
                    } else {
                        stats.promotions_wasted += 1;
                    }
                }
                let b = &e.blocks[idx];
                // an in-flight promotion still counts as run-extending (it
                // will land); a settled non-gpu or dropped block is a hole
                if b.pending.is_none() && (b.tier != Tier::GpuHbm || b.kv_dropped) {
                    suffix_ok = false;
                }
            }
        }
        landed
    }

    /// Demote one other sequence's run-start block (policy's choice) one
    /// tier down to free gpu capacity.  Returns false when there is no
    /// candidate or no room below.
    fn evict_gpu_victim(&mut self, exclude_seq: u64) -> bool {
        let bt = self.block_tokens;
        let mut cands: Vec<BlockView> = Vec::new();
        for (&sid, e) in self.seqs.iter() {
            if sid == exclude_seq {
                continue;
            }
            // the lowest block of the top gpu run: evicting it keeps the
            // remaining residency a suffix
            let mut run_start: Option<usize> = None;
            let mut idx = Self::valid_blocks_of(e, bt);
            while idx > 0 {
                idx -= 1;
                let b = &e.blocks[idx];
                if b.tier == Tier::GpuHbm && b.pending.is_none() && !b.kv_dropped {
                    run_start = Some(idx);
                } else {
                    break;
                }
            }
            if let Some(idx) = run_start {
                cands.push(BlockView {
                    id: BlockId { seq: sid, idx },
                    tokens: Self::block_tokens_at(e, idx, bt),
                    start_token: idx * bt,
                    seq_len: e.tokens,
                    last_use: e.last_use,
                    split_l: e.split_l,
                });
            }
        }
        if cands.is_empty() {
            return false;
        }
        let v = cands[self.policy.victim(&cands)];
        let Some(bytes) = self.seqs.get(&v.id.seq).map(|e| e.block_bytes) else { return false };
        let dest = self
            .mgr
            .grab(Tier::Pinned, bytes)
            .map(|g| (Tier::Pinned, g))
            .or_else(|| self.mgr.grab(Tier::CpuDram, bytes).map(|g| (Tier::CpuDram, g)));
        let Some((tier, guard)) = dest else { return false };
        self.mgr.migrate_sync(bytes);
        let Some(e) = self.seqs.get_mut(&v.id.seq) else { return false };
        let b = &mut e.blocks[v.id.idx];
        b.guard = Some(guard); // gpu reservation released
        b.tier = tier;
        self.stats.demotions += 1;
        true
    }

    /// Bytes that dropping every currently-droppable KV prefix would free
    /// (the contiguous chain of fully-valid, host-resident, settled blocks
    /// above each sequence's dropped prefix) — the admission pre-check's
    /// reclaim ceiling.
    fn reclaimable_bytes(&self) -> u64 {
        let bt = self.block_tokens;
        let mut total = 0u64;
        for e in self.seqs.values() {
            let kv = e.block_bytes - e.block_bytes.div_ceil(3);
            let mut idx = e.blocks.iter().take_while(|b| b.kv_dropped).count();
            while idx < e.blocks.len() {
                let b = &e.blocks[idx];
                if (idx + 1) * bt > e.tokens || b.tier == Tier::GpuHbm || b.pending.is_some() {
                    break;
                }
                total += kv;
                idx += 1;
            }
        }
        total
    }

    /// Drop the KV (keep X) of one policy-chosen block, freeing ≈⅔ of its
    /// bytes in place.  Only fully-valid, host-resident blocks extending a
    /// sequence's contiguous dropped prefix qualify.  Returns bytes freed.
    fn reclaim_kv_one(&mut self) -> Option<u64> {
        let bt = self.block_tokens;
        let mut cands: Vec<BlockView> = Vec::new();
        for (&sid, e) in self.seqs.iter() {
            let idx = e.blocks.iter().take_while(|b| b.kv_dropped).count();
            if idx >= e.blocks.len() {
                continue;
            }
            let b = &e.blocks[idx];
            if (idx + 1) * bt > e.tokens || b.tier == Tier::GpuHbm || b.pending.is_some() {
                continue;
            }
            cands.push(BlockView {
                id: BlockId { seq: sid, idx },
                tokens: bt,
                start_token: idx * bt,
                seq_len: e.tokens,
                last_use: e.last_use,
                split_l: e.split_l,
            });
        }
        if cands.is_empty() {
            return None;
        }
        let v = cands[self.policy.victim(&cands)];
        let (tier, bytes) = {
            let e = self.seqs.get(&v.id.seq)?;
            (e.blocks[v.id.idx].tier, e.block_bytes)
        };
        let x_bytes = bytes.div_ceil(3); // X is one of the three K/V/X tensors
        // shrink in place: release the full-block guard, re-grab X-only
        self.seqs.get_mut(&v.id.seq)?.blocks[v.id.idx].guard = None;
        let guard = self.mgr.grab(tier, x_bytes);
        let e = self.seqs.get_mut(&v.id.seq)?;
        let b = &mut e.blocks[v.id.idx];
        b.guard = guard;
        b.kv_dropped = true;
        self.stats.kv_drops += 1;
        Some(bytes - x_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::policy::Lru;

    const BB: u64 = 3000; // block bytes in these tests

    fn store(gpu_blocks: u64, pinned_blocks: u64, dram_blocks: u64) -> KvStore {
        KvStore::new(
            KvStoreConfig {
                gpu_bytes: gpu_blocks * BB,
                pinned_bytes: pinned_blocks * BB,
                dram_bytes: dram_blocks * BB,
                block_tokens: 16,
                link: LinkConfig::unthrottled(),
            },
            Box::new(Lru),
        )
    }

    fn poll_landed_until(s: &mut KvStore, want: usize) -> usize {
        // unthrottled transfers land almost immediately, but on a worker
        // thread; poll until `want` promotions have landed
        let mut total = 0;
        for _ in 0..500 {
            total += s.complete_landed();
            if total >= want {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        total
    }

    #[test]
    fn admit_places_cold_first_in_host_tiers_and_rolls_back() {
        let mut s = store(1, 1, 2);
        s.admit(1, 3 * BB, 3).unwrap();
        assert_eq!(s.tier_used(Tier::CpuDram), 2 * BB);
        assert_eq!(s.tier_used(Tier::Pinned), BB);
        // the gpu tier is a promotion-only cache: admission never parks
        // blocks there, so eviction can always reclaim it
        assert_eq!(s.tier_used(Tier::GpuHbm), 0);
        // host tiers full, nothing droppable (tokens == 0) → fails clean
        let used_before: u64 = Tier::ALL.iter().map(|&t| s.tier_used(t)).sum();
        assert!(s.admit(2, 2 * BB, 2).is_err());
        let used_after: u64 = Tier::ALL.iter().map(|&t| s.tier_used(t)).sum();
        assert_eq!(used_before, used_after, "failed admit must roll back");
    }

    #[test]
    fn release_frees_everything() {
        let mut s = store(0, 0, 4);
        s.admit(1, 4 * BB, 4).unwrap();
        assert_eq!(s.tier_used(Tier::CpuDram), 4 * BB);
        s.release(1);
        assert_eq!(s.tier_used(Tier::CpuDram), 0);
    }

    #[test]
    fn device_suffix_sync_respects_gpu_budget() {
        let mut s = store(1, 0, 4); // gpu fits one block
        s.admit(1, 4 * BB, 4).unwrap();
        s.touch(1, 40, 0); // 3 valid blocks (16+16+8 tokens)
        // engine says its window covers 24 tokens (top partial 8 + one full 16)
        let r = s.sync_device_suffix(1, 24);
        assert_eq!(r, 8, "budget backs only the top block (8 valid tokens)");
        assert_eq!(s.tier_used(Tier::GpuHbm), BB);
        assert_eq!(s.stats().device_syncs, 1);
    }

    #[test]
    fn promotions_prefetch_and_land() {
        let mut s = store(2, 0, 4);
        s.admit(1, 4 * BB, 4).unwrap();
        s.touch(1, 32, 0); // blocks 0 and 1 valid
        let issued = s.begin_promotions(1, 2);
        assert_eq!(issued, 2);
        assert_eq!(s.pending_count(), 2);
        // in-flight promotions do not count as resident yet
        assert_eq!(s.gpu_resident_tokens(1), 0);
        assert_eq!(poll_landed_until(&mut s, 2), 2);
        assert_eq!(s.gpu_resident_tokens(1), 32);
        assert_eq!(s.tier_used(Tier::GpuHbm), 2 * BB);
        assert_eq!(s.tier_used(Tier::CpuDram), 2 * BB, "source reservations released");
        assert_eq!(s.stats().promotions_landed, 2);
    }

    #[test]
    fn full_gpu_tier_evicts_other_seq_via_policy() {
        let mut s = store(1, 1, 4);
        s.admit(1, 2 * BB, 2).unwrap();
        s.admit(2, 2 * BB, 2).unwrap();
        s.touch(1, 16, 0);
        assert_eq!(s.sync_device_suffix(1, 16), 16, "seq 1 takes the gpu block");
        s.touch(2, 16, 0); // seq 2 is now more recent than seq 1
        let issued = s.begin_promotions(2, 1);
        assert_eq!(issued, 1, "eviction must have made room");
        assert!(s.stats().demotions >= 1);
        assert_eq!(s.gpu_resident_tokens(1), 0, "lru victim demoted");
        poll_landed_until(&mut s, 1);
        assert_eq!(s.gpu_resident_tokens(2), 16);
    }

    #[test]
    fn admission_reclaims_by_dropping_kv() {
        let mut s = store(0, 0, 2);
        s.admit(1, 2 * BB, 2).unwrap();
        s.touch(1, 32, 32); // both blocks fully valid
        assert_eq!(s.tier_used(Tier::CpuDram), 2 * BB);
        // nothing free, but seq 1's prefix KV is droppable: 2 drops free
        // 2 × ⅔·BB = 4000 ≥ BB, so the new block fits
        s.admit(2, BB, 1).unwrap();
        assert!(s.stats().kv_drops >= 1);
        assert_eq!(s.kv_dropped_tokens(1) % 16, 0);
        assert!(s.kv_dropped_tokens(1) >= 16);
        assert!(s.tier_used(Tier::CpuDram) <= 2 * BB);
    }

    #[test]
    fn dropped_prefix_reports_planner_floor() {
        let mut s = store(0, 0, 2);
        s.admit(1, 2 * BB, 2).unwrap();
        s.touch(1, 32, 32);
        assert_eq!(s.kv_dropped_tokens(1), 0);
        let freed = s.reclaim_kv_one().expect("droppable");
        assert_eq!(freed, BB - BB.div_ceil(3), "KV is ⅔ of the K/V/X block");
        assert_eq!(s.tier_used(Tier::CpuDram), BB + BB.div_ceil(3));
        assert_eq!(s.kv_dropped_tokens(1), 16);
    }
}
