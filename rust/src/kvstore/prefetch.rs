//! Asynchronous block prefetch: queue a group's blocks for promotion
//! *ahead* of its decode step.
//!
//! The prefetcher is a thin policy layer over the store's
//! [`MigrationEngine`](super::MigrationEngine): it queues promotions with
//! [`MigrationClass::Prefetch`] — launched after demand promotions and
//! demotions (but still ahead of disk spill) when the serving loop grants
//! the step's link-byte budget via [`KvStore::pump_migrations`] — and
//! bounds the number of open migrations so a burst of groups cannot swamp
//! the queue with transfers that will be stale by the time they land.  A
//! prefetch that reaches a disk-resident block issues that block's
//! disk→dram hop, warming the two-hop path ahead of demand.  The serving loop calls
//! [`Prefetcher::poll`] once per step to install finished migrations,
//! then [`Prefetcher::pump`] per decode group to keep the queue fed.

use super::migrate::MigrationClass;
use super::store::KvStore;

/// Per-prefetcher counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefetchStats {
    pub issued: u64,
    pub landed: u64,
    pub throttled: u64,
}

/// Bounded-depth asynchronous promoter over a [`KvStore`].
#[derive(Debug)]
pub struct Prefetcher {
    max_inflight: usize,
    stats: PrefetchStats,
}

impl Prefetcher {
    pub fn new(max_inflight: usize) -> Self {
        Prefetcher { max_inflight: max_inflight.max(1), stats: PrefetchStats::default() }
    }

    pub fn stats(&self) -> PrefetchStats {
        self.stats
    }

    /// Install every landed migration; returns how many.
    pub fn poll(&mut self, store: &mut KvStore) -> usize {
        let landed = store.poll_landed();
        self.stats.landed += landed as u64;
        landed
    }

    /// Keep up to `blocks` promotions queued for `seq`.  The run's *next*
    /// extension is demand traffic ([`MigrationClass::Promote`]: launched
    /// first, rides the link at high priority — the group needs it to
    /// shrink its very next step's transfer); deeper lookahead blocks are
    /// speculative [`MigrationClass::Prefetch`] and respect the global
    /// open-migration bound.  The demand block is admitted even at zero
    /// room as long as this group has nothing open itself, so one group's
    /// queued prefetch backlog can never starve another group's next-step
    /// residency (total open stays ≤ bound + one per group).  Returns
    /// promotions queued now.
    pub fn pump(&mut self, store: &mut KvStore, seq: u64, blocks: usize) -> usize {
        let room = self.max_inflight.saturating_sub(store.pending_count());
        let mut issued = 0;
        if blocks > 0 && (room > 0 || store.pending_count_of(seq) == 0) {
            issued = store.begin_promotions(seq, 1, MigrationClass::Promote);
        }
        // the demand walk finding nothing means the speculative walk would
        // find nothing either (same break point) — skip the re-walk, which
        // would also double-count a cool-down skip
        if issued > 0 {
            let spec = blocks.saturating_sub(1).min(room.saturating_sub(issued));
            if spec > 0 {
                issued += store.begin_promotions(seq, spec, MigrationClass::Prefetch);
            }
        }
        if issued == 0 && room == 0 {
            self.stats.throttled += 1;
        }
        self.stats.issued += issued as u64;
        issued
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::policy::Lru;
    use crate::kvstore::store::KvStoreConfig;
    use crate::transfer::LinkConfig;

    const BB: u64 = 2048;

    fn slow_store(gpu_blocks: u64) -> KvStore {
        // slow enough that promotions stay in flight across polls
        let link = LinkConfig { bytes_per_sec: 50e3, latency_s: 0.0, chunk_bytes: 1 << 10 };
        KvStore::new(
            KvStoreConfig {
                gpu_bytes: gpu_blocks * BB,
                pinned_bytes: 8 * BB,
                dram_bytes: 8 * BB,
                disk_bytes: 0,
                block_tokens: 16,
                nvme_link: LinkConfig::nvme_below(&link),
                link,
                wire_elem_bytes: 4.0,
                promote_cooldown: 0,
                spill_cooldown: 0,
                spill_floor: 0.0,
                spill_watermark: 0.0,
                spill_max_per_step: 2,
                shared_host: None,
            },
            Box::new(Lru),
        )
    }

    #[test]
    fn pump_bounds_open_depth() {
        let mut store = slow_store(8);
        store.admit(1, 8 * BB, 8).unwrap();
        store.touch(1, 128, 0); // all 8 blocks valid
        let mut pf = Prefetcher::new(2);
        assert_eq!(pf.pump(&mut store, 1, 8), 2, "depth-capped");
        assert_eq!(store.pending_count(), 2);
        assert_eq!(pf.pump(&mut store, 1, 8), 0, "no room until something lands");
        assert_eq!(pf.stats().throttled, 1);
    }

    #[test]
    fn poll_lands_and_frees_depth() {
        let mut store = slow_store(4);
        store.admit(1, 4 * BB, 4).unwrap();
        store.touch(1, 64, 0);
        let mut pf = Prefetcher::new(2);
        pf.pump(&mut store, 1, 4);
        store.pump_migrations(u64::MAX); // grant link budget: queued → in flight
        // wait the slow link out, then land
        let mut landed = 0;
        for _ in 0..500 {
            landed += pf.poll(&mut store);
            if landed >= 2 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(landed, 2);
        assert_eq!(store.pending_count(), 0);
        assert!(store.gpu_resident_tokens(1) > 0);
        // freed depth lets the next pump queue again
        assert!(pf.pump(&mut store, 1, 4) > 0);
        assert_eq!(pf.stats().landed, 2);
    }
}
