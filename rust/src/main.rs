//! `kvpr` — CLI for the KVPR reproduction.
//!
//! Subcommands:
//!   generate  — run the real engine on a prompt (row-by-row)
//!   serve     — start the coordinator and replay a synthetic request trace
//!   sim       — simulate a paper-scale configuration and print the report
//!   plan      — print the LP's split-point trajectory (Fig 12 style)
//!   profile   — calibrate the local emulated link + recompute artifacts

use std::collections::HashMap;
use std::path::Path;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use kvpr::config::{HardwareConfig, ModelConfig, WorkloadConfig};
use kvpr::coordinator::{Batcher, Server, ServerConfig, Submit};
use kvpr::engine::{Engine, EngineConfig, EnginePolicy};
use kvpr::model::ByteTokenizer;
use kvpr::profiler::SystemProfile;
use kvpr::scheduler::{CostModel, Planner, SchedulePolicy};
use kvpr::sim::{simulate_decode, Policy, RunConfig};
use kvpr::transfer::{Link, LinkConfig};
use kvpr::util::table::Table;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Tiny flag parser: `--key value` pairs after the subcommand.
fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                map.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                map.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    map
}

fn flag<T: std::str::FromStr>(f: &HashMap<String, String>, key: &str, default: T) -> T {
    f.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn engine_policy(name: &str) -> Result<EnginePolicy> {
    Ok(match name {
        "kvpr" => EnginePolicy::Kvpr,
        "kvpr-fused" => EnginePolicy::KvprFused,
        "full" | "accelerate" => EnginePolicy::FullTransferSync,
        "full-overlap" | "flexgen" => EnginePolicy::FullTransferOverlap,
        "alisa" => EnginePolicy::AlisaSequential,
        other => bail!("unknown engine policy '{other}'"),
    })
}

fn sim_policy(name: &str) -> Result<Policy> {
    Ok(match name {
        "kvpr" => Policy::Kvpr,
        "kvpr-nohide" => Policy::KvprNoHide,
        "flexgen" => Policy::FlexGen,
        "accelerate" => Policy::Accelerate,
        "deepspeed" => Policy::DeepSpeed,
        "alisa" => Policy::AlisaLike,
        "fastdecode" => Policy::FastDecode,
        other => bail!("unknown sim policy '{other}'"),
    })
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    let flags = parse_flags(&argv[1..]);
    let artifacts = flags
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".to_string());

    match cmd.as_str() {
        "generate" => {
            let prompt = flags
                .get("prompt")
                .cloned()
                .unwrap_or_else(|| "the quick brown fox".into());
            let gen_len: usize = flag(&flags, "gen", 16);
            let bw: f64 = flag(&flags, "bandwidth-mbps", 30.0) * 1e6;
            let policy = engine_policy(flags.get("policy").map(|s| s.as_str()).unwrap_or("kvpr"))?;
            let mut cfg = EngineConfig::new(policy);
            cfg.link = LinkConfig::with_bandwidth(bw);
            let engine = Engine::new(Path::new(&artifacts), cfg)?;
            let tok = ByteTokenizer::new();
            let ids = vec![tok.encode(&prompt, 32)];
            let r = engine.generate(&ids, gen_len)?;
            println!("prompt:  {prompt}");
            println!("output:  {:?}", tok.decode(&r.tokens[0]));
            println!("tokens:  {:?}", r.tokens[0]);
            println!("splits:  {:?}", r.metrics.splits);
            println!(
                "prefill {:.3}s  decode {:.3}s  ({:.1} tok/s)",
                r.metrics.prefill_s,
                r.metrics.decode_s,
                r.metrics.decode_tok_per_s()
            );
            println!("breakdown: {:?}", r.metrics.breakdown);
        }
        "serve" => {
            let n_req: usize = flag(&flags, "requests", 8);
            let gen_len: usize = flag(&flags, "gen", 12);
            let bw: f64 = flag(&flags, "bandwidth-mbps", 30.0) * 1e6;
            let policy = engine_policy(flags.get("policy").map(|s| s.as_str()).unwrap_or("kvpr"))?;
            let mut ecfg = EngineConfig::new(policy);
            ecfg.link = LinkConfig::with_bandwidth(bw);
            let mut scfg = ServerConfig::new(&artifacts, ecfg);
            scfg.batcher = Batcher::new(flag(&flags, "max-batch", 4), Duration::from_millis(25));
            let server = Server::start(scfg)?;
            let prompts = [
                "the quick brown fox",
                "kv cache partial recomputation",
                "pcie is the bottleneck",
                "overlap compute and transfer",
            ];
            let handles: Vec<_> = (0..n_req)
                .map(|i| {
                    let p = prompts[i % prompts.len()];
                    server.dispatch((p, gen_len)).pop().unwrap()
                })
                .collect();
            for (i, h) in handles.into_iter().enumerate() {
                let r = h.wait()?;
                println!(
                    "req {i}: queue {:.3}s decode {:.3}s total {:.3}s  text {:?}",
                    r.queue_s, r.decode_s, r.total_s, r.text
                );
            }
            let (mean, p50, p99) = server.metrics().latency_stats();
            println!(
                "served {} requests in {} batches | latency mean {:.3}s p50 {:.3}s p99 {:.3}s | {:.1} tok/s",
                server.metrics().requests(),
                server.metrics().batches(),
                mean,
                p50,
                p99,
                server.metrics().tok_per_s()
            );
            server.shutdown()?;
        }
        "sim" => {
            let model = ModelConfig::by_name(&flag::<String>(&flags, "model", "opt-6.7b".into()))
                .context("unknown model")?;
            let hw = HardwareConfig::by_name(&flag::<String>(&flags, "hw", "a100".into()))
                .context("unknown hardware")?;
            let policy = sim_policy(&flag::<String>(&flags, "policy", "kvpr".into()))?;
            let prompt: usize = flag(&flags, "prompt", 512);
            let gen: usize = flag(&flags, "gen", 32);
            let objective: String = flag(&flags, "objective", "throughput".into());
            let wl = match objective.as_str() {
                "latency" => WorkloadConfig::latency_oriented(prompt, gen),
                _ => WorkloadConfig::throughput_oriented(prompt, gen),
            };
            let report = simulate_decode(&RunConfig::new(model.clone(), hw.clone(), wl, policy));
            let mut t = Table::new(
                &format!("sim: {} on {} [{}]", model.name, hw.name, policy.name()),
                &["metric", "value"],
            );
            t.row(&["decode (s)".into(), format!("{:.3}", report.decode_s)]);
            t.row(&["tokens/s".into(), format!("{:.1}", report.tok_per_s)]);
            t.row(&["gpu util".into(), format!("{:.1}%", report.gpu_util * 100.0)]);
            t.row(&["link util".into(), format!("{:.1}%", report.link_util * 100.0)]);
            t.row(&[
                "peak mem".into(),
                kvpr::util::fmt_bytes(report.peak_gpu_bytes),
            ]);
            t.row(&["tasks".into(), report.n_tasks.to_string()]);
            println!("{}", t.render());
        }
        "plan" => {
            let model = ModelConfig::by_name(&flag::<String>(&flags, "model", "opt-6.7b".into()))
                .context("unknown model")?;
            let hw = HardwareConfig::by_name(&flag::<String>(&flags, "hw", "a100".into()))
                .context("unknown hardware")?;
            let batch: usize = flag(&flags, "batch", 64);
            let prompt: usize = flag(&flags, "prompt", 128);
            let gen: usize = flag(&flags, "gen", 32);
            let cost = CostModel::from_hardware(&hw, &model, batch);
            let planner = Planner::new(cost, SchedulePolicy::RowByRow, vec![], prompt);
            let traj = planner.split_trajectory(prompt, gen);
            println!("optimal split l* per generated token (prompt {prompt}, batch {batch}):");
            println!("{traj:?}");
        }
        "profile" => {
            let bw: f64 = flag(&flags, "bandwidth-mbps", 30.0) * 1e6;
            let link = Link::new(LinkConfig::with_bandwidth(bw));
            let runtime = kvpr::runtime::Runtime::load(Path::new(&artifacts))?;
            let p = SystemProfile::measure(&link, &runtime, 4)?;
            println!("{p:#?}");
            let cm = p.cost_model(&runtime.manifest().model);
            println!("cost model: {cm:#?}");
            println!("A/C ratio: {:.3}", cm.recompute_to_transfer_ratio());
            // the measured root of the declarative tier chain the serving
            // loop stacks its configured capacities below
            println!("topology root: {:#?}", p.topology(0));
        }
        "help" | "--help" | "-h" => print_help(),
        other => bail!("unknown command '{other}' (try `kvpr help`)"),
    }
    Ok(())
}

fn print_help() {
    println!(
        "kvpr — I/O-aware LLM inference with KV cache partial recomputation (ACL 2025 reproduction)

USAGE: kvpr <command> [--flag value ...]

COMMANDS
  generate  --prompt <text> --gen <n> --policy kvpr|full|full-overlap|kvpr-fused|alisa
            --bandwidth-mbps <mb>        run the real engine on one prompt
  serve     --requests <n> --gen <n> --max-batch <n> --policy ...
                                         start the coordinator, replay a trace
  sim       --model opt-6.7b|opt-13b|opt-30b|llama2-7b|llama2-13b
            --hw a100|rtx5000 --policy kvpr|flexgen|accelerate|deepspeed|alisa|fastdecode
            --prompt <n> --gen <n> --objective latency|throughput
                                         paper-scale simulation report
  plan      --model ... --hw ... --batch <n> --prompt <n> --gen <n>
                                         print the LP split trajectory (Fig 12)
  profile   --bandwidth-mbps <mb>        calibrate link + recompute artifacts"
    );
}
