//! Host/device memory accounting.
//!
//! The paper reports GPU *peak* memory alongside latency (Tables 3–4) and
//! plots the memory line in Fig 8; this module is the bookkeeping that makes
//! those numbers reproducible.  Buffers themselves are plain `Vec<f32>`s in
//! host RAM (the "device" is the PJRT CPU client), but every allocation on
//! the emulated device goes through [`MemPool`] so capacity limits and peak
//! usage behave like the real 40 GB HBM.

mod pool;

pub use pool::{MemPool, PoolGuard};
