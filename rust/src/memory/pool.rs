//! Byte-accounted memory pool with capacity enforcement and peak tracking.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

/// A named pool ("gpu-hbm", "cpu-dram", "pinned") tracking used/peak bytes.
/// Clone-cheap (Arc-shared): the engine's threads account into one pool.
#[derive(Debug, Clone)]
pub struct MemPool {
    inner: Arc<Inner>,
}

#[derive(Debug)]
struct Inner {
    name: String,
    capacity: u64,
    used: AtomicU64,
    peak: AtomicU64,
}

impl MemPool {
    pub fn new(name: &str, capacity_bytes: u64) -> Self {
        MemPool {
            inner: Arc::new(Inner {
                name: name.to_string(),
                capacity: capacity_bytes,
                used: AtomicU64::new(0),
                peak: AtomicU64::new(0),
            }),
        }
    }

    pub fn name(&self) -> &str {
        &self.inner.name
    }

    pub fn capacity(&self) -> u64 {
        self.inner.capacity
    }

    pub fn used(&self) -> u64 {
        self.inner.used.load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> u64 {
        self.inner.peak.load(Ordering::Relaxed)
    }

    pub fn available(&self) -> u64 {
        self.capacity().saturating_sub(self.used())
    }

    /// Reserve `bytes`; fails when the pool would exceed capacity — this is
    /// how "KV cache no longer fits on the GPU" manifests in the engine.
    pub fn alloc(&self, bytes: u64) -> Result<PoolGuard> {
        let prev = self.inner.used.fetch_add(bytes, Ordering::SeqCst);
        if prev + bytes > self.inner.capacity {
            self.inner.used.fetch_sub(bytes, Ordering::SeqCst);
            bail!(
                "pool '{}' exhausted: want {} but only {} of {} free",
                self.inner.name,
                bytes,
                self.inner.capacity - prev.min(self.inner.capacity),
                self.inner.capacity
            );
        }
        self.inner.peak.fetch_max(prev + bytes, Ordering::SeqCst);
        Ok(PoolGuard { pool: self.clone(), bytes })
    }

    /// Reset the peak marker (between bench phases).
    pub fn reset_peak(&self) {
        self.inner.peak.store(self.used(), Ordering::SeqCst);
    }

    fn release(&self, bytes: u64) {
        self.inner.used.fetch_sub(bytes, Ordering::SeqCst);
    }
}

/// RAII reservation; dropping returns the bytes to the pool.
#[derive(Debug)]
pub struct PoolGuard {
    pool: MemPool,
    bytes: u64,
}

impl PoolGuard {
    pub fn bytes(&self) -> u64 {
        self.bytes
    }
}

impl Drop for PoolGuard {
    fn drop(&mut self) {
        self.pool.release(self.bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let p = MemPool::new("t", 100);
        let g1 = p.alloc(60).unwrap();
        assert_eq!(p.used(), 60);
        let g2 = p.alloc(40).unwrap();
        assert_eq!(p.used(), 100);
        assert_eq!(p.available(), 0);
        drop(g1);
        assert_eq!(p.used(), 40);
        drop(g2);
        assert_eq!(p.used(), 0);
        assert_eq!(p.peak(), 100);
    }

    #[test]
    fn over_capacity_fails_cleanly() {
        let p = MemPool::new("t", 100);
        let _g = p.alloc(80).unwrap();
        assert!(p.alloc(30).is_err());
        // failed alloc must not leak accounting
        assert_eq!(p.used(), 80);
        assert!(p.alloc(20).is_ok());
    }

    #[test]
    fn peak_tracks_high_water_mark() {
        let p = MemPool::new("t", 1000);
        {
            let _a = p.alloc(700).unwrap();
        }
        let _b = p.alloc(100).unwrap();
        assert_eq!(p.peak(), 700);
        p.reset_peak();
        assert_eq!(p.peak(), 100);
    }

    #[test]
    fn concurrent_accounting_is_exact() {
        // Under contention: `used` must return to zero once every guard is
        // dropped, and `peak` must be *exact* — all threads hold their
        // allocation across a barrier, so the high-water mark is forced to
        // be precisely n_threads × bytes.
        const THREADS: usize = 8;
        const BYTES: u64 = 10;
        const ROUNDS: usize = 50;
        let p = MemPool::new("t", THREADS as u64 * BYTES);
        let barrier = std::sync::Arc::new(std::sync::Barrier::new(THREADS));
        let mut handles = Vec::new();
        for _ in 0..THREADS {
            let p = p.clone();
            let barrier = barrier.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..ROUNDS {
                    // churn: allocate/free at random-ish interleavings...
                    let g = p.alloc(BYTES).expect("capacity fits all threads");
                    std::hint::black_box(&g);
                    drop(g);
                    // ...then all threads hold one allocation simultaneously
                    let g = p.alloc(BYTES).expect("capacity fits all threads");
                    barrier.wait(); // every thread holds BYTES here
                    std::hint::black_box(&g);
                    barrier.wait(); // nobody frees before everyone arrived
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(p.used(), 0, "used must return to zero");
        assert_eq!(
            p.peak(),
            THREADS as u64 * BYTES,
            "peak must be exactly the forced simultaneous maximum"
        );
    }

    #[test]
    fn concurrent_alloc_respects_capacity() {
        let p = MemPool::new("t", 1000);
        let mut handles = Vec::new();
        for _ in 0..8 {
            let p = p.clone();
            handles.push(std::thread::spawn(move || {
                let mut ok = 0;
                for _ in 0..100 {
                    if let Ok(g) = p.alloc(10) {
                        std::hint::black_box(&g);
                        ok += 1;
                    }
                }
                ok
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(p.used(), 0);
        assert!(p.peak() <= 1000);
    }
}
