//! The profiler module (paper §3.1).
//!
//! "The profiler module gathers system statistics, which provide insights
//! into hardware characteristics like PCIe bandwidth and GPU processing
//! speed."  Concretely:
//!
//! * [`profile_link`] — timed transfers of increasing size through the
//!   emulated PCIe [`Link`]; a least-squares fit of `t(bytes)` recovers
//!   (latency, bandwidth) exactly as one would calibrate real PCIe.
//! * [`profile_recompute`] — times the `recompute_b{B}_l{L}` artifacts at
//!   every L bucket and fits `t(l) = overhead + slope·l`; the slope is the
//!   LP's per-token recompute cost A, *measured*, not assumed.
//! * [`SystemProfile::measure`] — runs both and packages a [`CostModel`]
//!   for the scheduler.
//! * [`SystemProfile::topology`] — packages the measured wire as the root
//!   of a declarative [`TierTopology`]: the device⊃host chain the profiler
//!   can see on its own, which configuration extends with storage rungs
//!   and [`TierTopology::calibrated`] resolves — the **profiler →
//!   topology → plan → runtime** pipeline's first stage.
//!
//! Profiling runs once at engine startup (paper §7 notes the same static
//! assumption), off the request path.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::config::ModelConfig;
use crate::runtime::{ArgValue, Runtime};
use crate::scheduler::{CostModel, LinkSpec, TierTopology};
use crate::transfer::{Link, Priority};
use crate::util::stats::linear_fit;

/// Measured system characteristics.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemProfile {
    /// Effective link bandwidth, bytes/s.
    pub link_bytes_per_sec: f64,
    /// Fixed per-transfer latency, seconds.
    pub link_latency_s: f64,
    /// Fitted per-token KV recompute time at the profiled batch bucket.
    pub recompute_per_token_s: f64,
    /// Fitted fixed overhead of one recompute call.
    pub gpu_overhead_s: f64,
    /// Batch bucket the recompute fit was taken at.
    pub batch: usize,
}

impl SystemProfile {
    /// Full calibration: link probe + recompute probe.
    pub fn measure(link: &Link, runtime: &Runtime, batch: usize) -> Result<Self> {
        let (bw, lat) = profile_link(link);
        let (slope, intercept) = profile_recompute(runtime, batch)?;
        Ok(SystemProfile {
            link_bytes_per_sec: bw,
            link_latency_s: lat,
            recompute_per_token_s: slope,
            gpu_overhead_s: intercept,
            batch,
        })
    }

    /// The measured primary wire as a topology [`LinkSpec`].
    pub fn link_spec(&self) -> LinkSpec {
        LinkSpec { bytes_per_sec: self.link_bytes_per_sec, latency_s: self.link_latency_s }
    }

    /// The measured chain this profile can vouch for: a device tier over
    /// one host tier joined by the probed wire.  `gpu_capacity_bytes` is
    /// configuration, not measurement, so the caller supplies it (0 for
    /// "inherit").  Deeper chains are built by stacking storage rungs
    /// below this root ([`TierTopology::with_disk`]) and calibrating the
    /// new links against the same measured spec
    /// ([`TierTopology::calibrated`]).
    pub fn topology(&self, gpu_capacity_bytes: u64) -> TierTopology {
        TierTopology::device_host(gpu_capacity_bytes, self.link_spec())
    }

    /// Cost model for the scheduler at this profile's batch bucket.
    pub fn cost_model(&self, model: &ModelConfig) -> CostModel {
        let kv_bytes = model.kv_bytes_per_layer(self.batch, 1) as f64;
        let act_bytes = model.act_bytes_per_layer(self.batch, 1) as f64;
        CostModel {
            recompute_per_token_s: self.recompute_per_token_s,
            transfer_kv_per_token_s: kv_bytes / self.link_bytes_per_sec,
            transfer_act_per_token_s: act_bytes / self.link_bytes_per_sec,
            gpu_overhead_s: self.gpu_overhead_s,
            link_latency_s: self.link_latency_s,
        }
    }
}

/// Probe the link with transfers of growing size; fit t = lat + bytes/bw.
pub fn profile_link(link: &Link) -> (f64, f64) {
    // element counts: 16 KB .. 2 MB
    let sizes = [4 << 10, 16 << 10, 64 << 10, 256 << 10, 512 << 10];
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for &n in &sizes {
        let src = Arc::new(vec![0.5f32; n]);
        // min of 4 runs — the minimum is the standard low-noise estimator
        // for microbenchmarks on a shared machine
        let mut best = f64::INFINITY;
        for _ in 0..4 {
            let t0 = Instant::now();
            link.submit(src.clone(), 0..n, Priority::Normal).wait();
            best = best.min(t0.elapsed().as_secs_f64());
        }
        xs.push((n * 4) as f64);
        ys.push(best);
    }
    let (lat, inv_bw) = linear_fit(&xs, &ys);
    let bw = if inv_bw > 0.0 { 1.0 / inv_bw } else { f64::INFINITY };
    (bw, lat.max(0.0))
}

/// Time the recompute artifacts at each L bucket; fit t(l) = c + a·l.
pub fn profile_recompute(runtime: &Runtime, batch: usize) -> Result<(f64, f64)> {
    let manifest = runtime.manifest();
    let model = manifest.model.clone();
    let h = model.hidden;
    let weights = crate::model::ModelWeights::generate(&model, 0xfeed);
    let w = weights.layer(0);

    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for &l in &manifest.l_buckets.clone() {
        let art = runtime.artifact(&manifest.recompute_name(batch, l))?;
        let x_pre = vec![0.1f32; batch * l * h];
        let args = [
            ArgValue::F32(&x_pre),
            ArgValue::F32(w.get("ln1_g")),
            ArgValue::F32(w.get("ln1_b")),
            ArgValue::F32(w.get("wk")),
            ArgValue::F32(w.get("bk")),
            ArgValue::F32(w.get("wv")),
            ArgValue::F32(w.get("bv")),
        ];
        // warmup + min of 5 — scheduling noise on a small shared box easily
        // doubles a single sample, which would flip the LP's decision
        art.call(&args)?;
        let mut best = f64::INFINITY;
        for _ in 0..5 {
            let t0 = Instant::now();
            art.call(&args)?;
            best = best.min(t0.elapsed().as_secs_f64());
        }
        xs.push(l as f64);
        ys.push(best);
    }
    let (intercept, slope) = linear_fit(&xs, &ys);
    Ok((slope.max(0.0), intercept.max(0.0)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transfer::LinkConfig;

    #[test]
    fn link_probe_recovers_bandwidth() {
        let _t = crate::util::timing_lock();
        let link = Link::new(LinkConfig {
            bytes_per_sec: 200e6,
            latency_s: 0.5e-3,
            chunk_bytes: 64 << 10,
        });
        let (bw, lat) = profile_link(&link);
        assert!((bw - 200e6).abs() / 200e6 < 0.35, "bw {bw}");
        assert!(lat < 5e-3, "lat {lat}");
    }

    #[test]
    fn profile_feeds_scheduler() {
        // synthetic profile → cost model → solver end-to-end
        let p = SystemProfile {
            link_bytes_per_sec: 100e6,
            link_latency_s: 1e-4,
            recompute_per_token_s: 5e-5,
            gpu_overhead_s: 1e-3,
            batch: 4,
        };
        let model = ModelConfig::tiny();
        let cm = p.cost_model(&model);
        // per-token kv transfer: 2·4·256·4 bytes / 100e6
        let want = (2 * 4 * 256 * 4) as f64 / 100e6;
        assert!((cm.transfer_kv_per_token_s - want).abs() < 1e-12);
        assert_eq!(cm.recompute_per_token_s, 5e-5);
        let solver =
            crate::scheduler::SplitSolver::new(cm, crate::scheduler::SchedulePolicy::RowByRow);
        let sol = solver.solve(100, 100);
        assert!(sol.l <= 100);
    }

    #[test]
    fn profile_roots_the_topology() {
        // the measured wire becomes the primary link of the declarative
        // chain; stacking a disk rung and calibrating derives its NVMe
        // shape from the same measurement — nothing drifts
        let p = SystemProfile {
            link_bytes_per_sec: 100e6,
            link_latency_s: 1e-4,
            recompute_per_token_s: 5e-5,
            gpu_overhead_s: 1e-3,
            batch: 4,
        };
        let topo = p.topology(1 << 20);
        assert_eq!(topo.len(), 2);
        assert_eq!(topo.primary_bytes_per_sec(), 100e6);
        assert_eq!(topo.tier(0).capacity_bytes, 1 << 20);
        let four = p
            .topology(0)
            .with_disk(1 << 30, 0.9)
            .calibrated(&p.link_spec());
        let disk = four.tier_named("disk-nvme").unwrap();
        assert!((four.hop_factor(disk) - 4.0).abs() < 1e-9);
    }
}
