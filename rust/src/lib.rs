//! # KVPR — I/O-Aware LLM Inference with KV Cache Partial Recomputation
//!
//! Reproduction of *"KVPR: Efficient LLM Inference with I/O-Aware KV Cache
//! Partial Recomputation"* (Jiang & Gao et al., ACL Findings 2025).
//!
//! The library is organised as the paper's three modules plus the substrates
//! they depend on:
//!
//! * [`profiler`] — measures link bandwidth and compute speed of the system
//!   (paper §3.1, "profiler module").
//! * [`scheduler`] — solves the integer linear program of Eq. (11) for the
//!   optimal KV-cache split point `l`, and builds row-by-row /
//!   column-by-column execution plans (paper §3.2).  Planning is
//!   topology-driven: one per-batch entry point
//!   ([`scheduler::Planner::plan_batch`]) folds the transfer term over a
//!   declarative [`scheduler::TierTopology`] chain and predicts the
//!   idle-link slack the serving loop grants to tier migrations.
//! * [`engine`] — the runtime module (paper §3.3): overlapped execution of
//!   transfer and recomputation with double buffering, pinned-memory pools
//!   and the fine-grained W_K/W_V-first MHA pipeline.  Exposes both
//!   whole-batch generation and the step-wise
//!   [`DecodeSession`](engine::DecodeSession) API.
//! * [`coordinator`] — serving front end: the **continuous-batching** event
//!   loop ([`coordinator::ContinuousServer`]: per-step admission and
//!   retirement, per-batch split re-planning, KV-budget backpressure), the
//!   whole-batch baseline server, and the data-parallel router.
//! * [`runtime`] — executes the AOT-compiled JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`) via PJRT (`--features pjrt`), or interprets
//!   them with the pure-Rust reference model when PJRT/artifacts are absent
//!   — same math, zero build-time dependencies.
//! * [`transfer`] — emulated CPU↔GPU PCIe link: a bandwidth-throttled copy
//!   engine with ordered streams and pinned host memory.
//! * [`memory`], [`kvcache`], [`model`] — device/host pools, the KV-cache
//!   manager (including group-wise 4-bit quantization) and the model-weight
//!   store.
//! * [`kvstore`] — the tiered, block-granular KV store: gpu-hbm / pinned /
//!   cpu-dram / disk-nvme block placement with one asynchronous migration
//!   lifecycle (queued → staged → in-flight → landed) for promotions,
//!   demotions, prefetch and capacity-aware disk spill under a per-step
//!   link-byte budget (disk hops ride their own slower NVMe wire), plus
//!   pluggable victim selection including the recompute-aware lenses
//!   (drop KV keep X, writeback-aware demotion, two-hop-aware spill) that
//!   generalise Eq. (11) into a capacity lever.
//! * [`sim`] — discrete-event simulator of the paper's testbeds (A100 +
//!   PCIe 4.0 x16, RTX 5000 + x8) used to regenerate every table and figure
//!   of the evaluation at paper scale.
//! * [`obs`] — observability: a zero-dependency step-level tracer
//!   (request / phase / migration lifecycle events on the decode-step
//!   virtual clock), plan-vs-actual residual telemetry, a flight recorder
//!   with anomaly-triggered JSON dumps, and a Chrome `trace_event`
//!   exporter (`examples/trace_dump.rs`); costs one branch when disabled.
//! * [`workload`] — deterministic trace generator (bursty/diurnal arrival
//!   processes, heavy-tailed context lengths, chat think-time gaps, RAG
//!   mixes as a declarative [`workload::WorkloadSpec`]); the same seeded
//!   trace replays through the continuous server (step-indexed admission)
//!   and the analytic kvstore sim, and `ServeMetrics` scores the served
//!   run against the mix's TTFT/TPOT SLOs.
//!
//! Python/JAX/Pallas participate only at build time (`make artifacts`); the
//! request path is pure Rust.

#![deny(rustdoc::broken_intra_doc_links)]
#![deny(rustdoc::private_intra_doc_links)]

pub mod config;
pub mod coordinator;
pub mod engine;
pub mod kvcache;
pub mod kvstore;
pub mod memory;
pub mod model;
pub mod obs;
pub mod paper;
pub mod profiler;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod transfer;
pub mod util;
pub mod workload;

pub use config::{HardwareConfig, ModelConfig, WorkloadConfig};
pub use scheduler::{SchedulePolicy, Scheduler, SplitSolver};

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Bench-only re-export of the staging transpose (the engine keeps it
/// private; `benches/perf_hotpath.rs` times it in isolation).
#[doc(hidden)]
pub fn engine_stage_padded_bench(
    rows_data: &[f32],
    n_rows: usize,
    batch: usize,
    hidden: usize,
    rows_per_batch: usize,
    out: &mut Vec<f32>,
) {
    engine::stage_padded_for_bench(rows_data, n_rows, batch, hidden, rows_per_batch, out)
}
