//! The emulated CPU↔GPU interconnect.
//!
//! DESIGN.md §2: we have no PCIe-attached GPU, so the link is a real
//! background thread that moves bytes between host-side and device-side
//! buffers at a configurable throttled bandwidth with a fixed per-transfer
//! latency.  Because the throttling happens on a *separate thread*, compute
//! (PJRT execution on the caller thread) and communication genuinely
//! overlap — the engine's KVPR pipeline wins wall-clock time for exactly
//! the reason the paper's does.
//!
//! * [`Link`] — ordered, prioritised copy engine (one per direction, like
//!   CUDA's H2D/D2H queues).  Priorities implement the fine-grained MHA
//!   pipeline (W_K/W_V jump the queue, paper Fig 5b).
//! * [`TransferHandle`] — awaitable completion event (CUDA-event analogue).
//! * [`PinnedPool`] — reusable staging buffers (pinned-memory analogue,
//!   paper §3.3 "Pinned memory"): steady-state decode allocates nothing.

mod link;
mod pinned;

pub use link::{Link, LinkConfig, LinkStats, Priority, TransferHandle, NVME_BANDWIDTH_FACTOR};
pub use pinned::PinnedPool;
