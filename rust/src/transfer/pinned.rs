//! Pinned staging-buffer pool.
//!
//! The paper (§3.3) pins the host buffers used for activation and weight
//! transfer so DMA can run asynchronously without page faults.  The analogue
//! here: a freelist of pre-sized `Vec<f32>` buffers, so the steady-state
//! decode loop performs **zero heap allocation** for staging — the property
//! the §Perf pass measures.

use std::collections::HashMap;
use std::sync::Mutex;

/// Size-bucketed freelist of reusable f32 buffers.
#[derive(Debug, Default)]
pub struct PinnedPool {
    free: Mutex<HashMap<usize, Vec<Vec<f32>>>>,
    hits: std::sync::atomic::AtomicU64,
    misses: std::sync::atomic::AtomicU64,
}

impl PinnedPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get a zero-length buffer with at least `capacity` elements reserved.
    pub fn get(&self, capacity: usize) -> Vec<f32> {
        let mut free = self.free.lock().unwrap();
        if let Some(list) = free.get_mut(&capacity) {
            if let Some(mut buf) = list.pop() {
                buf.clear();
                self.hits.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                return buf;
            }
        }
        drop(free);
        self.misses.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Vec::with_capacity(capacity)
    }

    /// Return a buffer to the pool (keyed by its capacity).
    pub fn put(&self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        let mut free = self.free.lock().unwrap();
        free.entry(buf.capacity()).or_default().push(buf);
    }

    /// Pre-populate `count` buffers of `capacity` elements (warmup).
    pub fn reserve(&self, capacity: usize, count: usize) {
        let mut free = self.free.lock().unwrap();
        let list = free.entry(capacity).or_default();
        for _ in 0..count {
            list.push(Vec::with_capacity(capacity));
        }
    }

    pub fn hit_rate(&self) -> f64 {
        let h = self.hits.load(std::sync::atomic::Ordering::Relaxed) as f64;
        let m = self.misses.load(std::sync::atomic::Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_roundtrip() {
        let pool = PinnedPool::new();
        let mut a = pool.get(1024);
        a.extend_from_slice(&[1.0, 2.0]);
        let cap = a.capacity();
        pool.put(a);
        let b = pool.get(cap);
        assert_eq!(b.len(), 0, "recycled buffer must come back cleared");
        assert_eq!(b.capacity(), cap);
        assert!(pool.hit_rate() > 0.0);
    }

    #[test]
    fn warmup_gives_hits() {
        let pool = PinnedPool::new();
        pool.reserve(256, 4);
        for _ in 0..4 {
            let b = pool.get(256);
            assert_eq!(b.capacity(), 256);
        }
        assert_eq!(pool.hit_rate(), 1.0);
    }

    #[test]
    fn miss_allocates() {
        let pool = PinnedPool::new();
        let b = pool.get(512);
        assert!(b.capacity() >= 512);
        assert_eq!(pool.hit_rate(), 0.0);
    }
}
