//! Pinned staging-buffer pool.
//!
//! The paper (§3.3) pins the host buffers used for activation and weight
//! transfer so DMA can run asynchronously without page faults.  The analogue
//! here: a freelist of pre-sized `Vec<f32>` buffers, so the steady-state
//! decode loop performs **zero heap allocation** for staging — the property
//! the §Perf pass measures.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::memory::{MemPool, PoolGuard};

/// Size-bucketed freelist of reusable f32 buffers.
///
/// With [`PinnedPool::with_accounting`] every buffer the pool *creates* is
/// charged against a byte-accounted [`MemPool`] for the lifetime of the
/// pinned region (real pinned allocators grow and stay pinned), so pinned
/// staging occupancy is visible to — and capped by — the "pinned" tier
/// budget of the kvstore.  When the budget is exhausted the buffer is still
/// handed out (staging must not fail mid-decode) but counted as an
/// unpinned fallback.
#[derive(Debug, Default)]
pub struct PinnedPool {
    free: Mutex<HashMap<usize, Vec<Vec<f32>>>>,
    hits: std::sync::atomic::AtomicU64,
    misses: std::sync::atomic::AtomicU64,
    account: Option<MemPool>,
    guards: Mutex<Vec<PoolGuard>>,
    unpinned_fallbacks: std::sync::atomic::AtomicU64,
}

impl PinnedPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// A pool whose created buffers are byte-accounted in `account`.
    pub fn with_accounting(account: MemPool) -> Self {
        PinnedPool { account: Some(account), ..Self::default() }
    }

    /// Get a zero-length buffer with at least `capacity` elements reserved.
    pub fn get(&self, capacity: usize) -> Vec<f32> {
        let mut free = self.free.lock().unwrap();
        if let Some(list) = free.get_mut(&capacity) {
            if let Some(mut buf) = list.pop() {
                buf.clear();
                self.hits.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                return buf;
            }
        }
        drop(free);
        self.misses.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if let Some(pool) = &self.account {
            match pool.alloc((capacity * 4) as u64) {
                Ok(g) => self.guards.lock().unwrap().push(g),
                Err(_) => {
                    self.unpinned_fallbacks
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            }
        }
        Vec::with_capacity(capacity)
    }

    /// Return a buffer to the pool (keyed by its capacity).
    pub fn put(&self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        let mut free = self.free.lock().unwrap();
        free.entry(buf.capacity()).or_default().push(buf);
    }

    /// Pre-populate `count` buffers of `capacity` elements (warmup).
    pub fn reserve(&self, capacity: usize, count: usize) {
        let mut free = self.free.lock().unwrap();
        let list = free.entry(capacity).or_default();
        for _ in 0..count {
            if let Some(pool) = &self.account {
                match pool.alloc((capacity * 4) as u64) {
                    Ok(g) => self.guards.lock().unwrap().push(g),
                    Err(_) => {
                        self.unpinned_fallbacks
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                }
            }
            list.push(Vec::with_capacity(capacity));
        }
    }

    pub fn hit_rate(&self) -> f64 {
        let h = self.hits.load(std::sync::atomic::Ordering::Relaxed) as f64;
        let m = self.misses.load(std::sync::atomic::Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }

    /// Buffers handed out unaccounted because the pinned budget was full.
    pub fn unpinned_fallbacks(&self) -> u64 {
        self.unpinned_fallbacks
            .load(std::sync::atomic::Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_roundtrip() {
        let pool = PinnedPool::new();
        let mut a = pool.get(1024);
        a.extend_from_slice(&[1.0, 2.0]);
        let cap = a.capacity();
        pool.put(a);
        let b = pool.get(cap);
        assert_eq!(b.len(), 0, "recycled buffer must come back cleared");
        assert_eq!(b.capacity(), cap);
        assert!(pool.hit_rate() > 0.0);
    }

    #[test]
    fn warmup_gives_hits() {
        let pool = PinnedPool::new();
        pool.reserve(256, 4);
        for _ in 0..4 {
            let b = pool.get(256);
            assert_eq!(b.capacity(), 256);
        }
        assert_eq!(pool.hit_rate(), 1.0);
    }

    #[test]
    fn miss_allocates() {
        let pool = PinnedPool::new();
        let b = pool.get(512);
        assert!(b.capacity() >= 512);
        assert_eq!(pool.hit_rate(), 0.0);
    }

    #[test]
    fn accounting_charges_created_buffers_only() {
        let mem = crate::memory::MemPool::new("pinned", 1 << 20);
        let pool = PinnedPool::with_accounting(mem.clone());
        let a = pool.get(256);
        assert_eq!(mem.used(), 256 * 4, "miss charges the pinned budget");
        let cap = a.capacity();
        pool.put(a);
        let _b = pool.get(cap);
        assert_eq!(mem.used(), 256 * 4, "recycled hit is not re-charged");
        assert_eq!(pool.unpinned_fallbacks(), 0);
    }

    #[test]
    fn exhausted_budget_falls_back_unpinned() {
        let mem = crate::memory::MemPool::new("pinned", 100);
        let pool = PinnedPool::with_accounting(mem.clone());
        let b = pool.get(1024); // 4 KiB wanted, 100 B budget
        assert!(b.capacity() >= 1024, "staging must still be served");
        assert_eq!(pool.unpinned_fallbacks(), 1);
        assert_eq!(mem.used(), 0);
    }

    #[test]
    fn reserve_is_accounted() {
        let mem = crate::memory::MemPool::new("pinned", 1 << 20);
        let pool = PinnedPool::with_accounting(mem.clone());
        pool.reserve(64, 4);
        assert_eq!(mem.used(), 4 * 64 * 4);
    }
}
