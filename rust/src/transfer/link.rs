//! Bandwidth-throttled, prioritised, ordered copy engine.

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Transfer priority. `High` models the fine-grained weight pipeline: W_K and
/// W_V are enqueued `High` so KV recomputation can start before the rest of
/// the MHA weights arrive (paper Fig 5b).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    Normal = 0,
    High = 1,
}

/// Link shaping parameters.
#[derive(Debug, Clone)]
pub struct LinkConfig {
    /// Modelled bandwidth in bytes/s.
    pub bytes_per_sec: f64,
    /// Fixed per-transfer latency in seconds (DMA setup analogue).
    pub latency_s: f64,
    /// Streaming chunk size in bytes — the granularity at which the worker
    /// paces itself (and at which a `High` transfer can overtake).
    pub chunk_bytes: usize,
}

impl LinkConfig {
    pub fn with_bandwidth(bytes_per_sec: f64) -> Self {
        LinkConfig { bytes_per_sec, latency_s: 30e-6, chunk_bytes: 64 << 10 }
    }

    /// An effectively-infinite link (tests that want zero shaping).
    pub fn unthrottled() -> Self {
        LinkConfig { bytes_per_sec: f64::INFINITY, latency_s: 0.0, chunk_bytes: 1 << 20 }
    }

    /// An NVMe-ish link derived from a PCIe-ish one: the disk tier's
    /// sequential bandwidth is [`NVME_BANDWIDTH_FACTOR`]× slower than the
    /// CPU↔GPU interconnect and each I/O pays a much larger fixed setup
    /// cost (queue submission + flash access vs DMA setup).
    ///
    /// [`TierTopology::calibrated`](crate::scheduler::TierTopology::calibrated)
    /// applies this exact derivation to every below-base rung whose link
    /// the configuration left unspecified, so the declarative chain and
    /// the emulated wires can never drift apart.
    pub fn nvme_below(pcie: &LinkConfig) -> Self {
        LinkConfig {
            bytes_per_sec: pcie.bytes_per_sec / NVME_BANDWIDTH_FACTOR,
            latency_s: pcie.latency_s.max(1e-6) * NVME_BANDWIDTH_FACTOR,
            chunk_bytes: pcie.chunk_bytes,
        }
    }
}

/// Interconnect-to-NVMe bandwidth gap used everywhere the disk tier is
/// modeled: [`LinkConfig::nvme_below`] shapes the emulated wire with it,
/// and the spill-scoring / planner / sim two-hop terms reuse it so cost
/// models never drift from the link model.  The 4× ratio mirrors the
/// PCIe-4.0-x16 (~32 GB/s) to datacenter-NVMe (~7 GB/s) gap the KV
/// management survey's storage hierarchy assumes.
pub const NVME_BANDWIDTH_FACTOR: f64 = 4.0;

/// Aggregate counters for utilization reporting (Fig 8-style).
#[derive(Debug, Default)]
pub struct LinkStats {
    pub transfers: AtomicU64,
    pub bytes: AtomicU64,
    /// Nanoseconds the worker spent actively moving data.
    pub busy_ns: AtomicU64,
}

impl LinkStats {
    pub fn busy_secs(&self) -> f64 {
        self.busy_ns.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn total_transfers(&self) -> u64 {
        self.transfers.load(Ordering::Relaxed)
    }
}

struct Request {
    /// Source data (host or device side); `None` models a store whose bytes
    /// we don't need back (D2H KV append — timing only, content already in
    /// the host cache).
    src: Option<Arc<Vec<f32>>>,
    range: std::ops::Range<usize>,
    priority: Priority,
    seq: u64,
    event: Arc<Event>,
}

// BinaryHeap is a max-heap: higher priority first, then *lower* seq first.
impl PartialEq for Request {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Request {}
impl PartialOrd for Request {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Request {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.priority
            .cmp(&other.priority)
            .then(other.seq.cmp(&self.seq))
    }
}

#[derive(Default)]
struct Event {
    state: Mutex<EventState>,
    cond: Condvar,
}

#[derive(Default)]
struct EventState {
    done: bool,
    data: Option<Vec<f32>>,
    completed_at: Option<Instant>,
}

/// Completion handle for a submitted transfer.
pub struct TransferHandle {
    event: Arc<Event>,
    bytes: u64,
}

impl TransferHandle {
    /// Block until the transfer lands; returns the copied data (empty for
    /// timing-only stores).
    pub fn wait(self) -> Vec<f32> {
        let mut st = self.event.state.lock().unwrap();
        while !st.done {
            st = self.event.cond.wait(st).unwrap();
        }
        st.data.take().unwrap_or_default()
    }

    /// Non-blocking completion check.
    pub fn is_done(&self) -> bool {
        self.event.state.lock().unwrap().done
    }

    pub fn bytes(&self) -> u64 {
        self.bytes
    }
}

struct Shared {
    queue: Mutex<BinaryHeap<Request>>,
    cond: Condvar,
    stop: AtomicBool,
    stats: LinkStats,
    seq: AtomicU64,
}

/// One direction of the interconnect (H2D or D2H).
pub struct Link {
    shared: Arc<Shared>,
    config: LinkConfig,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Link {
    pub fn new(config: LinkConfig) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(BinaryHeap::new()),
            cond: Condvar::new(),
            stop: AtomicBool::new(false),
            stats: LinkStats::default(),
            seq: AtomicU64::new(0),
        });
        let worker = {
            let shared = shared.clone();
            let config = config.clone();
            std::thread::Builder::new()
                .name("kvpr-link".into())
                .spawn(move || worker_loop(&shared, &config))
                .expect("spawn link worker")
        };
        Link { shared, config, worker: Some(worker) }
    }

    pub fn config(&self) -> &LinkConfig {
        &self.config
    }

    pub fn stats(&self) -> &LinkStats {
        &self.shared.stats
    }

    /// Ideal (un-queued) time this link needs for `bytes`.
    pub fn ideal_time(&self, bytes: u64) -> f64 {
        self.config.latency_s + bytes as f64 / self.config.bytes_per_sec
    }

    /// Enqueue a copy of `src[range]`; completion yields the copied values.
    pub fn submit(
        &self,
        src: Arc<Vec<f32>>,
        range: std::ops::Range<usize>,
        priority: Priority,
    ) -> TransferHandle {
        assert!(range.end <= src.len(), "transfer range out of bounds");
        let bytes = (range.len() * 4) as u64;
        self.push(Request {
            src: Some(src),
            range,
            priority,
            seq: self.shared.seq.fetch_add(1, Ordering::Relaxed),
            event: Arc::new(Event::default()),
        }, bytes)
    }

    /// Enqueue a timing-only transfer of `n_f32` elements (stores whose
    /// payload the caller already owns on the destination side).
    pub fn submit_timing(&self, n_f32: usize, priority: Priority) -> TransferHandle {
        let bytes = (n_f32 * 4) as u64;
        self.push(Request {
            src: None,
            range: 0..n_f32,
            priority,
            seq: self.shared.seq.fetch_add(1, Ordering::Relaxed),
            event: Arc::new(Event::default()),
        }, bytes)
    }

    fn push(&self, req: Request, bytes: u64) -> TransferHandle {
        let event = req.event.clone();
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push(req);
        }
        self.shared.cond.notify_one();
        TransferHandle { event, bytes }
    }

    /// Block until every queued transfer has drained.
    pub fn drain(&self) {
        loop {
            {
                let q = self.shared.queue.lock().unwrap();
                if q.is_empty() {
                    // worker may still be mid-transfer; a zero-byte marker
                    // flushes FIFO order
                }
            }
            let h = self.submit_timing(0, Priority::Normal);
            h.wait();
            let q = self.shared.queue.lock().unwrap();
            if q.is_empty() {
                return;
            }
        }
    }
}

impl Drop for Link {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cond.notify_all();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared, config: &LinkConfig) {
    loop {
        let req = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(req) = q.pop() {
                    break req;
                }
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.cond.wait(q).unwrap();
            }
        };
        let start = Instant::now();
        let n = req.range.len();
        let bytes = n * 4;
        let total = config.latency_s + bytes as f64 / config.bytes_per_sec;

        // copy in pacing chunks so long transfers stream like a DMA engine
        let mut out = Vec::with_capacity(if req.src.is_some() { n } else { 0 });
        let chunk_elems = (config.chunk_bytes / 4).max(1);
        let mut copied = 0usize;
        while copied < n {
            let take = chunk_elems.min(n - copied);
            if let Some(src) = &req.src {
                let lo = req.range.start + copied;
                out.extend_from_slice(&src[lo..lo + take]);
            }
            copied += take;
            if total.is_finite() && total > 0.0 {
                let frac = copied as f64 / n as f64;
                precise_wait_until(start + Duration::from_secs_f64(total * frac));
            }
        }
        if n == 0 && total.is_finite() && total > 0.0 {
            precise_wait_until(start + Duration::from_secs_f64(config.latency_s));
        }

        shared.stats.transfers.fetch_add(1, Ordering::Relaxed);
        shared.stats.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        shared
            .stats
            .busy_ns
            .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);

        let mut st = req.event.state.lock().unwrap();
        st.done = true;
        st.data = if req.src.is_some() { Some(out) } else { None };
        st.completed_at = Some(Instant::now());
        drop(st);
        req.event.cond.notify_all();
    }
}

/// Hybrid sleep/spin wait: coarse `thread::sleep` down to ~1.5 ms before the
/// deadline, then yield-spin — gives tens-of-µs accuracy without pegging a
/// core for long waits.
fn precise_wait_until(deadline: Instant) {
    loop {
        let now = Instant::now();
        if now >= deadline {
            return;
        }
        let remaining = deadline - now;
        if remaining > Duration::from_micros(1500) {
            std::thread::sleep(remaining - Duration::from_micros(1000));
        } else {
            std::hint::spin_loop();
            std::thread::yield_now();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(bw: f64) -> Link {
        Link::new(LinkConfig { bytes_per_sec: bw, latency_s: 0.0, chunk_bytes: 16 << 10 })
    }

    #[test]
    fn copies_data_exactly() {
        let link = mk(f64::INFINITY);
        let src = Arc::new((0..1000).map(|i| i as f32).collect::<Vec<_>>());
        let h = link.submit(src.clone(), 100..200, Priority::Normal);
        let out = h.wait();
        assert_eq!(out.len(), 100);
        assert_eq!(out[0], 100.0);
        assert_eq!(out[99], 199.0);
    }

    #[test]
    fn throttling_takes_expected_time() {
        let _t = crate::util::timing_lock();
        // 4 MB at 100 MB/s → 40 ms
        let link = mk(100e6);
        let src = Arc::new(vec![1.0f32; 1 << 20]);
        let t0 = Instant::now();
        link.submit(src, 0..(1 << 20), Priority::Normal).wait();
        let dt = t0.elapsed().as_secs_f64();
        assert!((0.038..0.12).contains(&dt), "took {dt}");
    }

    #[test]
    fn transfers_overlap_with_caller_compute() {
        let _t = crate::util::timing_lock();
        // The core property the whole engine relies on: the caller can do
        // work while the link moves bytes.  Long durations so scheduler
        // noise on a small box amortises.
        let link = mk(100e6); // 80 ms for 8 MB
        let src = Arc::new(vec![1.0f32; 2 << 20]);
        let t0 = Instant::now();
        let h = link.submit(src, 0..(2 << 20), Priority::Normal);
        // "compute" for ~60 ms on this thread
        let mut acc = 0.0f64;
        while t0.elapsed() < Duration::from_millis(60) {
            acc += 1.0;
            std::hint::black_box(acc);
        }
        h.wait();
        let dt = t0.elapsed().as_secs_f64();
        // serial execution would be ≥ 140 ms; overlapped ≈ 80 ms
        assert!(dt < 0.125, "no overlap: {dt}");
    }

    #[test]
    fn high_priority_overtakes_queued_normal() {
        let _t = crate::util::timing_lock();
        let link = mk(25e6);
        let big = Arc::new(vec![0.0f32; 256 << 10]); // ~40 ms each
        let _h1 = link.submit(big.clone(), 0..big.len(), Priority::Normal);
        let _h2 = link.submit(big.clone(), 0..big.len(), Priority::Normal);
        let small = Arc::new(vec![7.0f32; 1024]);
        let t0 = Instant::now();
        let hp = link.submit(small, 0..1024, Priority::High);
        hp.wait();
        let dt = t0.elapsed().as_secs_f64();
        // must finish after the in-flight transfer (~40 ms) but before both
        // queued normals (~80 ms)
        assert!(dt < 0.070, "high priority waited full queue: {dt}");
    }

    #[test]
    fn fifo_within_priority() {
        let link = mk(f64::INFINITY);
        let src = Arc::new(vec![0.0f32; 8]);
        let hs: Vec<_> = (0..16)
            .map(|_| link.submit(src.clone(), 0..8, Priority::Normal))
            .collect();
        for h in hs {
            h.wait(); // completes without deadlock, order is internal
        }
        assert_eq!(link.stats().total_transfers(), 16);
    }

    #[test]
    fn stats_accumulate() {
        let link = mk(f64::INFINITY);
        let src = Arc::new(vec![0.0f32; 1000]);
        link.submit(src.clone(), 0..1000, Priority::Normal).wait();
        link.submit(src, 0..500, Priority::Normal).wait();
        assert_eq!(link.stats().total_bytes(), 6000);
        assert_eq!(link.stats().total_transfers(), 2);
    }

    #[test]
    fn timing_only_store() {
        let link = mk(1e9);
        let h = link.submit_timing(250_000, Priority::Normal); // 1 MB → 1 ms
        let t0 = Instant::now();
        let out = h.wait();
        assert!(out.is_empty());
        assert!(t0.elapsed().as_secs_f64() < 0.05);
        assert_eq!(link.stats().total_bytes(), 1_000_000);
    }

    #[test]
    fn nvme_link_is_slower_than_its_pcie() {
        let pcie = LinkConfig::with_bandwidth(100e6);
        let nvme = LinkConfig::nvme_below(&pcie);
        assert!((nvme.bytes_per_sec - 25e6).abs() < 1.0);
        assert!(nvme.latency_s > pcie.latency_s);
        // the shared constant IS the shaped ratio (cost models reuse it)
        let ratio = pcie.bytes_per_sec / nvme.bytes_per_sec;
        assert!((ratio - NVME_BANDWIDTH_FACTOR).abs() < 1e-9);
    }

    #[test]
    fn topology_calibration_matches_nvme_below() {
        // the declarative chain's derived disk wire is this module's
        // nvme_below, number for number — the planner's hop surcharge and
        // the emulated NVMe link can never disagree
        let pcie = LinkConfig::with_bandwidth(100e6);
        let nvme = LinkConfig::nvme_below(&pcie);
        let topo = crate::scheduler::TierTopology::standard(1, 1, 1)
            .with_disk(1, 0.9)
            .calibrated(&crate::scheduler::LinkSpec::of(&pcie));
        let disk = topo.tier_named("disk-nvme").unwrap();
        let derived = topo.tier(disk).up.to_link_config(pcie.chunk_bytes);
        assert_eq!(derived.bytes_per_sec, nvme.bytes_per_sec);
        assert_eq!(derived.latency_s, nvme.latency_s);
        assert_eq!(derived.chunk_bytes, nvme.chunk_bytes);
    }

    #[test]
    fn ideal_time_formula() {
        let link = Link::new(LinkConfig {
            bytes_per_sec: 1e9,
            latency_s: 1e-4,
            chunk_bytes: 64 << 10,
        });
        let t = link.ideal_time(10_000_000);
        assert!((t - 0.0101).abs() < 1e-9);
    }

    #[test]
    fn drain_waits_for_queue() {
        let link = mk(200e6);
        let src = Arc::new(vec![0.0f32; 128 << 10]);
        for _ in 0..3 {
            let _ = link.submit(src.clone(), 0..src.len(), Priority::Normal);
        }
        link.drain();
        assert_eq!(link.stats().total_transfers() >= 3, true);
    }
}
