//! Per-token cost coefficients feeding the LP (paper Eq. 6, 8–10).
//!
//! Everything is normalised to *seconds per token of one layer at the given
//! batch size*, so the objective in `split.rs` is a direct transcription of
//! Eq. (10).  Two constructors: from a hardware description (simulator,
//! paper-scale) or from measured profiler output (engine, live system).

use crate::config::{HardwareConfig, ModelConfig};

/// Cost coefficients for one decoder layer at a fixed batch size.
#[derive(Debug, Clone, PartialEq)]
pub struct CostModel {
    /// A: seconds for the GPU to recompute one token's K+V (Eq. 8/9).
    pub recompute_per_token_s: f64,
    /// C: seconds for the link to move one token's K+V pair (Eq. 6/10).
    pub transfer_kv_per_token_s: f64,
    /// C/2 (or less under quantization): seconds to move one token's
    /// activations X.
    pub transfer_act_per_token_s: f64,
    /// Fixed GPU kernel-launch overhead charged once per recompute call.
    pub gpu_overhead_s: f64,
    /// Fixed link latency charged once per transfer.
    pub link_latency_s: f64,
}

impl CostModel {
    /// Analytic model from a hardware config (paper-scale simulation).
    pub fn from_hardware(hw: &HardwareConfig, model: &ModelConfig, batch: usize) -> Self {
        let kv_bytes = model.kv_bytes_per_layer(batch, 1) as f64;
        let act_bytes = model.act_bytes_per_layer(batch, 1) as f64;
        CostModel {
            recompute_per_token_s: model.recompute_flops(batch, 1) / hw.gpu_effective_flops(),
            transfer_kv_per_token_s: kv_bytes / hw.pcie_bytes_per_sec,
            transfer_act_per_token_s: act_bytes / hw.pcie_bytes_per_sec,
            gpu_overhead_s: hw.gpu_launch_overhead_s,
            link_latency_s: hw.pcie_latency_s,
        }
    }

    /// With group-wise 4-bit KV quantization on the wire (paper §4.4): the
    /// transferred KV shrinks; activations and recompute are unchanged.
    pub fn with_kv_quant(mut self, bytes_per_elem_ratio: f64) -> Self {
        self.transfer_kv_per_token_s *= bytes_per_elem_ratio;
        self
    }

    /// Ratio A/C — the quantity that decides where the split lands:
    /// l*/s' = C/(A+C) = 1/(1+ratio) in the row-by-row limit.
    pub fn recompute_to_transfer_ratio(&self) -> f64 {
        self.recompute_per_token_s / self.transfer_kv_per_token_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scale_coefficients_are_commensurate() {
        // DESIGN.md: for OPT-6.7B/b=32 on the A100 testbed, recomputing one
        // token's KV and transferring it cost the same order of magnitude —
        // that is exactly why a *mixed* split wins.
        let cm = CostModel::from_hardware(
            &HardwareConfig::a100_x16(),
            &ModelConfig::opt_6_7b(),
            32,
        );
        let r = cm.recompute_to_transfer_ratio();
        assert!((0.1..10.0).contains(&r), "ratio {r}");
    }

    #[test]
    fn activations_cost_half_of_kv() {
        let cm = CostModel::from_hardware(
            &HardwareConfig::a100_x16(),
            &ModelConfig::opt_13b(),
            8,
        );
        let half = cm.transfer_kv_per_token_s / 2.0;
        assert!((cm.transfer_act_per_token_s - half).abs() < 1e-12);
    }

    #[test]
    fn quantization_shrinks_only_kv() {
        let cm = CostModel::from_hardware(
            &HardwareConfig::a100_x16(),
            &ModelConfig::opt_13b(),
            8,
        );
        let q = cm.clone().with_kv_quant(0.3125); // 0.625 / 2 bytes
        assert!(q.transfer_kv_per_token_s < cm.transfer_kv_per_token_s * 0.32);
        assert_eq!(q.transfer_act_per_token_s, cm.transfer_act_per_token_s);
        assert_eq!(q.recompute_per_token_s, cm.recompute_per_token_s);
    }

    #[test]
    fn batch_scales_all_marginal_costs() {
        let hw = HardwareConfig::a100_x16();
        let m = ModelConfig::opt_6_7b();
        let c1 = CostModel::from_hardware(&hw, &m, 1);
        let c8 = CostModel::from_hardware(&hw, &m, 8);
        assert!((c8.recompute_per_token_s / c1.recompute_per_token_s - 8.0).abs() < 1e-9);
        assert!((c8.transfer_kv_per_token_s / c1.transfer_kv_per_token_s - 8.0).abs() < 1e-9);
    }
}
