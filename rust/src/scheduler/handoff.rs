//! Plan handoff between pipeline stages: validity tokens for plans solved
//! ahead of the step they execute in.
//!
//! The pipelined step runtime ([`crate::engine::pipeline`]) solves step
//! N+1's [`StepPlan`]s on a worker thread *while* step N computes.  Those
//! plans were solved against step-N state — but admissions, retirements,
//! landed migrations or a slid residency window can change a group's true
//! [`PlanInput`] before the plan is consumed.  The correctness seam is the
//! **validity token**: a [`PlanTicket`] carries the exact `PlanInput` the
//! plan was solved against, and redemption compares it (`PlanInput` is
//! `PartialEq`, all plain data) with the input the serving loop would have
//! solved inline.  Equal ⇒ the prebuilt plan *is* the plan a serial solve
//! would produce, byte for byte — adopt it.  Anything else ⇒ fall back to
//! an inline re-solve, and count it ([`HandoffReport`]).  Either way the
//! executed plan is identical to serial mode's, which is why the pipelined
//! loop can pin bit-identical tokens against the serial oracle.
//!
//! ```
//! use kvpr::scheduler::{PlanHandoff, PlanInput, Redemption, StepPlan};
//!
//! // worker solved two groups' plans against step-N state
//! let solved = |kv: usize| (PlanInput::new(vec![kv; 4]), StepPlan::full(1e-3, 0));
//! let (in_a, plan_a) = solved(64);
//! let (in_b, plan_b) = solved(96);
//! let mut handoff = PlanHandoff::new();
//! handoff.push(1, in_a.clone(), plan_a);
//! handoff.push(2, in_b, plan_b);
//!
//! // group 1 is unchanged at handoff: its prebuilt plan is adopted
//! assert!(matches!(handoff.redeem(1, &in_a), Redemption::Hit(_)));
//! // group 2 retired and group 3 was admitted in its place: no ticket
//! assert!(matches!(handoff.redeem(3, &PlanInput::new(vec![32; 4])), Redemption::Missing));
//! let report = handoff.into_report();
//! assert_eq!((report.hits, report.fallbacks), (1, 1));
//! assert!(!report.fully_prestaged());
//! ```

use super::plan::{PlanInput, StepPlan};

/// One pre-solved plan plus the exact input it was solved against — the
/// validity token the serving loop checks at handoff.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanTicket {
    /// Stable id of the decode group the plan was solved for.
    pub group: u64,
    /// The predicted [`PlanInput`] (step-N+1 state as projected at step N).
    pub input: PlanInput,
    /// The plan [`Planner::plan_batch`](super::Planner::plan_batch)
    /// produced for that input.
    pub plan: StepPlan,
}

/// Outcome of redeeming one group's ticket at handoff.
#[derive(Debug, Clone, PartialEq)]
pub enum Redemption {
    /// The predicted input matches the actual one: the prebuilt plan is
    /// exactly what an inline solve would return — use it.
    Hit(StepPlan),
    /// A ticket existed but the group's state moved under it (landed
    /// migration, slid residency window, dropped-KV floor change): the
    /// caller must re-solve inline.
    Stale,
    /// No ticket for this group (admitted after the prestage round, or the
    /// round's ticket was consumed): the caller must solve inline.
    Missing,
}

/// What one prestage round's redemption added up to; feeds
/// `ServeMetrics` pipeline totals and the flight-recorder replan streak.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HandoffReport {
    /// Prebuilt plans adopted unchanged.
    pub hits: u64,
    /// Inline re-solves forced by a stale or missing ticket.
    pub fallbacks: u64,
}

impl HandoffReport {
    /// A step counts as prestaged when every plan it executed came out of
    /// the handoff — one mid-handoff admission/retirement/migration is
    /// enough to break it.
    pub fn fully_prestaged(&self) -> bool {
        self.fallbacks == 0 && self.hits > 0
    }
}

/// The batch of [`PlanTicket`]s one prestage round produced, with
/// redemption accounting.  Built on the stage worker, redeemed (once per
/// group) on the serving thread at the next step's plan phase.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PlanHandoff {
    tickets: Vec<PlanTicket>,
    report: HandoffReport,
}

impl PlanHandoff {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a group's pre-solved plan with its validity token.
    pub fn push(&mut self, group: u64, input: PlanInput, plan: StepPlan) {
        self.tickets.push(PlanTicket { group, input, plan });
    }

    /// Tickets not yet redeemed.
    pub fn len(&self) -> usize {
        self.tickets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tickets.is_empty()
    }

    /// Redeem `group`'s ticket against the input an inline solve would use
    /// right now.  Consumes the ticket; every non-[`Redemption::Hit`]
    /// outcome is counted as a fallback re-solve in the report.
    pub fn redeem(&mut self, group: u64, actual: &PlanInput) -> Redemption {
        match self.tickets.iter().position(|t| t.group == group) {
            Some(i) => {
                let t = self.tickets.swap_remove(i);
                if t.input == *actual {
                    self.report.hits += 1;
                    Redemption::Hit(t.plan)
                } else {
                    self.report.fallbacks += 1;
                    Redemption::Stale
                }
            }
            None => {
                self.report.fallbacks += 1;
                Redemption::Missing
            }
        }
    }

    /// The running redemption tally (final once every live group planned).
    pub fn report(&self) -> HandoffReport {
        self.report
    }

    /// Consume the handoff, returning the tally.  Unredeemed tickets (a
    /// group that retired wholesale before its plan was needed) are
    /// dropped silently: nothing re-solved, nothing to count.
    pub fn into_report(self) -> HandoffReport {
        self.report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ticket_input(kv: usize, resident: usize) -> PlanInput {
        PlanInput::new(vec![kv; 4]).resident(resident)
    }

    fn plan() -> StepPlan {
        StepPlan::full(2.5e-3, 512)
    }

    #[test]
    fn matching_input_redeems_the_prebuilt_plan() {
        let mut h = PlanHandoff::new();
        h.push(7, ticket_input(64, 8), plan());
        match h.redeem(7, &ticket_input(64, 8)) {
            Redemption::Hit(p) => assert_eq!(p, plan()),
            other => panic!("expected hit, got {other:?}"),
        }
        assert_eq!(h.report(), HandoffReport { hits: 1, fallbacks: 0 });
        assert!(h.report().fully_prestaged());
    }

    #[test]
    fn a_landed_migration_between_solve_and_submit_goes_stale() {
        // the worker predicted resident=8; a promotion landed at the next
        // poll and grew the window — the ticket must not redeem
        let mut h = PlanHandoff::new();
        h.push(7, ticket_input(64, 8), plan());
        assert_eq!(h.redeem(7, &ticket_input(64, 16)), Redemption::Stale);
        assert_eq!(h.report(), HandoffReport { hits: 0, fallbacks: 1 });
    }

    #[test]
    fn mid_handoff_retirement_forces_exactly_one_counted_fallback() {
        // prestage round solved plans for groups 1 and 2 against step-N
        // state; between solve and submit group 2 retired and group 3 was
        // admitted in its place.  Group 1 redeems its prebuilt plan; group
        // 3 has no ticket and must re-solve inline — exactly one counted
        // fallback, and group 2's orphaned ticket costs nothing.
        let mut h = PlanHandoff::new();
        h.push(1, ticket_input(64, 0), plan());
        h.push(2, ticket_input(96, 0), plan());
        assert!(matches!(h.redeem(1, &ticket_input(64, 0)), Redemption::Hit(_)));
        assert_eq!(h.redeem(3, &ticket_input(32, 0)), Redemption::Missing);
        let report = h.into_report();
        assert_eq!(report.fallbacks, 1, "exactly one fallback re-solve");
        assert_eq!(report.hits, 1);
        assert!(!report.fully_prestaged());
    }

    #[test]
    fn tickets_are_single_use() {
        let mut h = PlanHandoff::new();
        h.push(1, ticket_input(64, 0), plan());
        assert!(matches!(h.redeem(1, &ticket_input(64, 0)), Redemption::Hit(_)));
        assert_eq!(h.redeem(1, &ticket_input(64, 0)), Redemption::Missing);
        assert_eq!(h.report(), HandoffReport { hits: 1, fallbacks: 1 });
    }

    #[test]
    fn empty_round_reports_nothing_prestaged() {
        let h = PlanHandoff::new();
        assert!(h.is_empty());
        assert!(!h.report().fully_prestaged(), "no hits ⇒ not a prestaged step");
    }
}
