//! The integer LP of paper Eq. (11): pick `l` minimising
//!
//! ```text
//! t(l) = M_X[0:l]/v_com  +  max( N_KV[0:l]/v_gpu , M_KV[l:s']/v_com )
//!        └─ column-by-column only ─┘
//! subject to 0 ≤ l ≤ l_max
//! ```
//!
//! With one integer variable the LP has a closed form: the max of an
//! increasing and a decreasing affine function is unimodal, so the optimum
//! is at their crossing (rounded both ways) or at a boundary.  `solve`
//! evaluates that candidate set exactly; `solve_exhaustive` is the O(s')
//! oracle the property tests compare against.

use super::cost::CostModel;
use super::SchedulePolicy;

/// An LP solution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Split {
    /// Optimal number of tokens to recompute on the GPU.
    pub l: usize,
    /// Predicted per-layer step time at this split (Eq. 10).
    pub time_s: f64,
    /// Predicted step time at l = 0 (pure transfer) for comparison.
    pub baseline_s: f64,
}

impl Split {
    /// Predicted speedup over pure transfer.
    pub fn speedup(&self) -> f64 {
        self.baseline_s / self.time_s
    }
}

/// Solver for the optimal split point.
#[derive(Debug, Clone)]
pub struct SplitSolver {
    pub cost: CostModel,
    pub policy: SchedulePolicy,
}

impl SplitSolver {
    pub fn new(cost: CostModel, policy: SchedulePolicy) -> Self {
        SplitSolver { cost, policy }
    }

    /// Eq. (10): per-layer step time if the first `l` of `s_prime` cached
    /// tokens are recomputed and the rest transferred.
    pub fn objective(&self, l: usize, s_prime: usize) -> f64 {
        assert!(l <= s_prime, "l {l} > s' {s_prime}");
        let c = &self.cost;
        let lf = l as f64;
        let rest = (s_prime - l) as f64;

        let t_recomp = if l > 0 { c.gpu_overhead_s + c.recompute_per_token_s * lf } else { 0.0 };
        let t_rest = if s_prime > l { c.link_latency_s + c.transfer_kv_per_token_s * rest } else { 0.0 };
        let t_act = if l > 0 { c.link_latency_s + c.transfer_act_per_token_s * lf } else { 0.0 };

        match self.policy {
            // row-by-row drops the activation term (activations stream in
            // ahead of the max() stage; Eq. 10 "first term omitted")
            SchedulePolicy::RowByRow => t_recomp.max(t_rest),
            SchedulePolicy::ColumnByColumn => t_act + t_recomp.max(t_rest),
        }
    }

    /// Closed-form integer solve over 0 ≤ l ≤ l_max.
    ///
    /// A minimal plan-and-predict round trip: with balanced per-token costs
    /// (recomputing one token costs what transferring it costs) the LP lands
    /// mid-sequence and halves the predicted step time versus pure transfer:
    ///
    /// ```
    /// use kvpr::scheduler::{CostModel, SchedulePolicy, SplitSolver};
    /// let cost = CostModel {
    ///     recompute_per_token_s: 1e-6,   // A, Eq. 8/9
    ///     transfer_kv_per_token_s: 1e-6, // C, Eq. 6
    ///     transfer_act_per_token_s: 5e-7,
    ///     gpu_overhead_s: 0.0,
    ///     link_latency_s: 0.0,
    /// };
    /// let solver = SplitSolver::new(cost, SchedulePolicy::RowByRow);
    /// let split = solver.solve(1000, 1000); // s' = 1000 cached tokens
    /// assert!((499..=501).contains(&split.l));
    /// assert!(split.time_s <= split.baseline_s);
    /// assert!((split.speedup() - 2.0).abs() < 0.01);
    /// ```
    pub fn solve(&self, s_prime: usize, l_max: usize) -> Split {
        let l_max = l_max.min(s_prime);
        let c = &self.cost;

        // crossing of t_recomp (increasing) and t_rest (decreasing):
        //   o_g + A·l = lat + C·(s' - l)   →   l = (lat + C·s' − o_g)/(A + C)
        // (for column-by-column the +act term is affine-increasing, which
        // can only pull the optimum left; the candidate set below covers it
        // because the objective is still piecewise-affine with breakpoints
        // only at the crossing and the boundaries)
        let a = c.recompute_per_token_s;
        let cc = c.transfer_kv_per_token_s;
        let cross = (c.link_latency_s + cc * s_prime as f64 - c.gpu_overhead_s) / (a + cc);

        let mut candidates = vec![0usize, l_max];
        if cross.is_finite() && cross > 0.0 {
            let f = cross.floor() as usize;
            candidates.push(f.min(l_max));
            candidates.push((f + 1).min(l_max));
        }
        // column-by-column: the activation slope can move the interior
        // optimum off the crossing onto the transfer-bound segment's best
        // point, which is also the crossing — but the recompute-bound
        // segment now has slope (act + A) > 0, so its best point is the
        // crossing too. Boundaries + crossing remain sufficient. We add
        // crossing±1 to absorb integer rounding.
        if cross.is_finite() && cross >= 1.0 {
            candidates.push(((cross.floor() as usize).saturating_sub(1)).min(l_max));
        }

        let best = candidates
            .into_iter()
            .map(|l| (l, self.objective(l, s_prime)))
            .min_by(|x, y| x.1.partial_cmp(&y.1).unwrap().then(x.0.cmp(&y.0)))
            .unwrap();

        Split { l: best.0, time_s: best.1, baseline_s: self.objective(0, s_prime) }
    }

    /// O(s') brute force — the oracle for property tests.
    pub fn solve_exhaustive(&self, s_prime: usize, l_max: usize) -> Split {
        let l_max = l_max.min(s_prime);
        let best = (0..=l_max)
            .map(|l| (l, self.objective(l, s_prime)))
            .min_by(|x, y| x.1.partial_cmp(&y.1).unwrap().then(x.0.cmp(&y.0)))
            .unwrap();
        Split { l: best.0, time_s: best.1, baseline_s: self.objective(0, s_prime) }
    }

    /// Pick the best *available* split from the static artifact buckets
    /// (plus l = 0 meaning the full-transfer path).  `kv_len` bounds
    /// feasibility: we can only recompute a prefix that exists.
    pub fn quantize_to_buckets(&self, s_prime: usize, buckets: &[usize], kv_len: usize) -> usize {
        self.quantize_to_buckets_floor(s_prime, buckets, kv_len, 0)
    }

    /// [`SplitSolver::quantize_to_buckets`] with a feasibility floor:
    /// buckets below `l_floor` are excluded, and `l = 0` is admissible
    /// only when the floor is zero (a dropped-KV prefix forces the
    /// recompute path to cover it).  Falls back to 0 when no bucket
    /// satisfies the floor — the caller degrades to full transfer.
    pub fn quantize_to_buckets_floor(
        &self,
        s_prime: usize,
        buckets: &[usize],
        kv_len: usize,
        l_floor: usize,
    ) -> usize {
        let mut best: Option<(usize, f64)> = if l_floor == 0 {
            Some((0, self.objective(0, s_prime)))
        } else {
            None
        };
        for &b in buckets {
            if b >= l_floor && b <= kv_len && b <= s_prime {
                let t = self.objective(b, s_prime);
                let better = match best {
                    Some((_, bt)) => t < bt,
                    None => true,
                };
                if better {
                    best = Some((b, t));
                }
            }
        }
        best.map(|(l, _)| l).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HardwareConfig, ModelConfig};
    use crate::util::prng::{check_property, prop_cases};

    fn cm(a: f64, c: f64) -> CostModel {
        CostModel {
            recompute_per_token_s: a,
            transfer_kv_per_token_s: c,
            transfer_act_per_token_s: c / 2.0,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        }
    }

    #[test]
    fn balanced_costs_split_in_the_middle() {
        // A == C, no overheads → crossing at s'/2
        let s = SplitSolver::new(cm(1e-6, 1e-6), SchedulePolicy::RowByRow);
        let sol = s.solve(1000, 1000);
        assert!((499..=501).contains(&sol.l), "l = {}", sol.l);
        // and the step time halves vs pure transfer
        assert!((sol.speedup() - 2.0).abs() < 0.01, "speedup {}", sol.speedup());
    }

    #[test]
    fn free_recompute_wants_everything() {
        // A → 0: recompute all s' tokens
        let s = SplitSolver::new(cm(1e-12, 1e-6), SchedulePolicy::RowByRow);
        assert_eq!(s.solve(512, 512).l, 512);
    }

    #[test]
    fn expensive_recompute_wants_nothing() {
        // A ≫ C: pure transfer
        let s = SplitSolver::new(cm(1e-3, 1e-9), SchedulePolicy::RowByRow);
        assert_eq!(s.solve(512, 512).l, 0);
    }

    #[test]
    fn l_max_caps_the_split() {
        let s = SplitSolver::new(cm(1e-9, 1e-6), SchedulePolicy::RowByRow);
        let sol = s.solve(1000, 128); // paper constraint l ≤ s (prompt len)
        assert_eq!(sol.l, 128);
    }

    #[test]
    fn row_by_row_matches_paper_fraction() {
        // l*/s' = C/(A+C) without overheads
        let a = 0.7e-6;
        let c = 1.3e-6;
        let s = SplitSolver::new(cm(a, c), SchedulePolicy::RowByRow);
        let sol = s.solve(10_000, 10_000);
        let want = c / (a + c) * 10_000.0;
        assert!((sol.l as f64 - want).abs() <= 1.0, "{} vs {want}", sol.l);
    }

    #[test]
    fn column_schedule_recomputes_less() {
        // paying C/2·l for activations shifts the optimum left (or equal)
        let cost = cm(1e-6, 1e-6);
        let row = SplitSolver::new(cost.clone(), SchedulePolicy::RowByRow).solve(1000, 1000);
        let col = SplitSolver::new(cost, SchedulePolicy::ColumnByColumn).solve(1000, 1000);
        assert!(col.l <= row.l, "col {} row {}", col.l, row.l);
    }

    #[test]
    fn overheads_disable_tiny_recompute() {
        // with a large launch overhead, recomputing 1 token can't pay off
        let mut c = cm(1e-9, 1e-9);
        c.gpu_overhead_s = 1.0;
        let s = SplitSolver::new(c, SchedulePolicy::RowByRow);
        assert_eq!(s.solve(100, 100).l, 0);
    }

    #[test]
    fn closed_form_matches_exhaustive_paper_scale() {
        for (model, batch) in [
            (ModelConfig::opt_6_7b(), 32),
            (ModelConfig::opt_13b(), 32),
            (ModelConfig::opt_30b(), 16),
        ] {
            for policy in [SchedulePolicy::RowByRow, SchedulePolicy::ColumnByColumn] {
                let cost = CostModel::from_hardware(&HardwareConfig::a100_x16(), &model, batch);
                let s = SplitSolver::new(cost, policy);
                for s_prime in [128usize, 300, 1024, 1153] {
                    let fast = s.solve(s_prime, s_prime);
                    let slow = s.solve_exhaustive(s_prime, s_prime);
                    assert_eq!(fast.l, slow.l, "{} s'={s_prime} {policy:?}", model.name);
                    assert!((fast.time_s - slow.time_s).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn property_closed_form_is_optimal() {
        check_property("split_optimality", prop_cases(60), |rng| {
            let a = 10f64.powf(rng.next_f64() * 6.0 - 9.0); // 1e-9 .. 1e-3
            let c = 10f64.powf(rng.next_f64() * 6.0 - 9.0);
            let mut cost = cm(a, c);
            cost.gpu_overhead_s = rng.next_f64() * 1e-4;
            cost.link_latency_s = rng.next_f64() * 1e-4;
            let policy = if rng.next_f64() < 0.5 {
                SchedulePolicy::RowByRow
            } else {
                SchedulePolicy::ColumnByColumn
            };
            let solver = SplitSolver::new(cost, policy);
            let s_prime = 1 + rng.index(2000);
            let l_max = 1 + rng.index(s_prime);
            let fast = solver.solve(s_prime, l_max);
            let slow = solver.solve_exhaustive(s_prime, l_max);
            if (fast.time_s - slow.time_s).abs() > 1e-15 + 1e-9 * slow.time_s {
                return Err(format!(
                    "fast l={} t={} vs exhaustive l={} t={} (s'={s_prime}, l_max={l_max}, {policy:?})",
                    fast.l, fast.time_s, slow.l, slow.time_s
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn property_solution_never_worse_than_baseline() {
        check_property("split_beats_baseline", 40, |rng| {
            let cost = cm(
                10f64.powf(rng.next_f64() * 4.0 - 8.0),
                10f64.powf(rng.next_f64() * 4.0 - 8.0),
            );
            let solver = SplitSolver::new(cost, SchedulePolicy::RowByRow);
            let s_prime = 1 + rng.index(1500);
            let sol = solver.solve(s_prime, s_prime);
            if sol.time_s <= sol.baseline_s + 1e-15 {
                Ok(())
            } else {
                Err(format!("t {} > baseline {}", sol.time_s, sol.baseline_s))
            }
        });
    }

    #[test]
    fn property_monotone_in_gpu_speed() {
        // a faster GPU (smaller A) never wants to recompute fewer tokens
        check_property("split_monotone_gpu", 30, |rng| {
            let c = 1e-6;
            let a1 = 10f64.powf(rng.next_f64() * 3.0 - 7.5);
            let a2 = a1 * (1.0 + rng.next_f64() * 10.0);
            let s_prime = 10 + rng.index(1000);
            let l1 = SplitSolver::new(cm(a1, c), SchedulePolicy::RowByRow)
                .solve(s_prime, s_prime)
                .l;
            let l2 = SplitSolver::new(cm(a2, c), SchedulePolicy::RowByRow)
                .solve(s_prime, s_prime)
                .l;
            if l1 >= l2 {
                Ok(())
            } else {
                Err(format!("faster GPU recomputes less: {l1} < {l2}"))
            }
        });
    }

    #[test]
    fn bucket_quantization_picks_best_feasible() {
        let solver = SplitSolver::new(cm(1e-6, 1e-6), SchedulePolicy::RowByRow);
        let buckets = [32, 64, 96];
        // optimum ≈ s'/2 = 60 → nearest best feasible bucket is 64
        assert_eq!(solver.quantize_to_buckets(120, &buckets, 120), 64);
        // kv_len too short for 64 → 32
        assert_eq!(solver.quantize_to_buckets(120, &buckets, 40), 32);
        // recompute hopeless → 0
        let bad = SplitSolver::new(cm(1.0, 1e-9), SchedulePolicy::RowByRow);
        assert_eq!(bad.quantize_to_buckets(120, &buckets, 120), 0);
    }

    #[test]
    fn bucket_floor_excludes_small_splits() {
        let solver = SplitSolver::new(cm(1e-6, 1e-6), SchedulePolicy::RowByRow);
        let buckets = [32, 64, 96];
        // floor 0 ≡ the unfloored quantisation
        assert_eq!(
            solver.quantize_to_buckets_floor(120, &buckets, 120, 0),
            solver.quantize_to_buckets(120, &buckets, 120)
        );
        // a recompute-hopeless model is still forced onto the floor bucket
        let bad = SplitSolver::new(cm(1.0, 1e-9), SchedulePolicy::RowByRow);
        assert_eq!(bad.quantize_to_buckets_floor(120, &buckets, 120, 32), 32);
        // no bucket satisfies the floor → degrade to 0 (full transfer)
        assert_eq!(solver.quantize_to_buckets_floor(120, &buckets, 20, 32), 0);
    }

    #[test]
    fn bucket_choice_never_worse_than_neighbours() {
        let solver = SplitSolver::new(
            CostModel::from_hardware(&HardwareConfig::a100_x16(), &ModelConfig::opt_6_7b(), 32),
            SchedulePolicy::RowByRow,
        );
        let buckets = [32, 64, 96];
        for s_prime in [96usize, 100, 128] {
            let l = solver.quantize_to_buckets(s_prime, &buckets, s_prime);
            let t = solver.objective(l, s_prime);
            for &alt in buckets.iter().chain(std::iter::once(&0)) {
                if alt <= s_prime {
                    assert!(t <= solver.objective(alt, s_prime) + 1e-15);
                }
            }
        }
    }
}
