//! The declarative tier topology the whole planning pipeline hangs off.
//!
//! KVPR's pitch is a fully automated profiler → scheduler → runtime
//! pipeline, but hardware shapes keep growing: PR 2 added host tiers,
//! PR 4 an NVMe disk tier, and the roadmap wants sharded workers.  Every
//! one of those used to fork the planner's closed form into a new entry
//! point (a bare-lane, a 3-tier and a 4-tier variant of `plan_batch`).
//! The KV-offloading bottleneck analyses model the hierarchy as an
//! arbitrary chain of capacity/bandwidth stages instead — so this module
//! makes the chain **data**:
//!
//! * [`LinkSpec`] — one wire's measured (or declared) bandwidth + latency.
//! * [`TierSpec`] — one storage rung: capacity, the wire its blocks cross
//!   toward the tier above, the wire element width migrations charge, and
//!   an optional occupancy watermark above which the rung proactively
//!   spills one tier down.
//! * [`TierTopology`] — the ordered chain, top (device) first, plus the
//!   index of the *base* tier the planner's per-step KV transfer term
//!   already reads from.  Fetching a token from any tier **below** the
//!   base pays every extra wire on the way up as a surcharge
//!   ([`TierTopology::hop_factor`]), which is how the planner folds the
//!   transfer term over however many hops the chain declares.
//!
//! The chain is built once at startup: the profiler measures the device
//! boundary ([`SystemProfile::topology`](crate::profiler::SystemProfile::topology)),
//! configuration stacks capacities below it, and
//! [`TierTopology::calibrated`] resolves any links the config left
//! unspecified from the measured primary wire (tiers below the base get
//! NVMe-shaped derivations, exactly matching
//! [`LinkConfig::nvme_below`](crate::transfer::LinkConfig::nvme_below)).
//! From then on a new tier — or a sharded worker's remote hop — is a data
//! change, not a planner fork.

use crate::transfer::{LinkConfig, NVME_BANDWIDTH_FACTOR};

/// One wire's shape: bandwidth and fixed per-transfer latency.  A spec
/// with zero bandwidth is **unresolved** — a placeholder the profiler
/// fills in via [`TierTopology::calibrated`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    /// Bytes per second; 0.0 means "derive from the primary wire".
    pub bytes_per_sec: f64,
    /// Fixed per-transfer latency in seconds.
    pub latency_s: f64,
}

impl LinkSpec {
    /// An unresolved placeholder: [`TierTopology::calibrated`] replaces it
    /// with the measured primary wire (host rungs) or an NVMe-shaped
    /// derivation of it (below-base rungs).
    pub fn unresolved() -> Self {
        LinkSpec { bytes_per_sec: 0.0, latency_s: 0.0 }
    }

    pub fn is_resolved(&self) -> bool {
        self.bytes_per_sec > 0.0 || self.bytes_per_sec.is_infinite()
    }

    /// The spec of an emulated [`LinkConfig`] wire.
    pub fn of(link: &LinkConfig) -> Self {
        LinkSpec { bytes_per_sec: link.bytes_per_sec, latency_s: link.latency_s }
    }

    /// Realise this spec as an emulated wire, pacing at `chunk_bytes`.
    pub fn to_link_config(&self, chunk_bytes: usize) -> LinkConfig {
        LinkConfig {
            bytes_per_sec: self.bytes_per_sec,
            latency_s: self.latency_s,
            chunk_bytes,
        }
    }
}

/// One rung of the tier chain.
#[derive(Debug, Clone, PartialEq)]
pub struct TierSpec {
    /// Pool name, matching the [`MemPool`](crate::memory::MemPool) naming
    /// convention ("gpu-hbm", "pinned", "cpu-dram", "disk-nvme", ...).
    pub name: String,
    /// Tier capacity in bytes (0 for "inherit/unbounded": the coordinator
    /// substitutes its KV budget for a zero-capacity top tier).
    pub capacity_bytes: u64,
    /// The wire this tier's blocks cross toward the tier above.  Ignored
    /// for the chain's top tier (nothing above it).
    pub up: LinkSpec,
    /// Wire bytes per f32 element migrations over `up` charge: 4.0 plain,
    /// 0.625 under int4 wire quantization.
    pub wire_elem_bytes: f64,
    /// Occupancy fraction above which this tier proactively spills cold
    /// blocks one rung down; 1.0 (or ≥ 1.0) disables proactive spill.
    pub spill_watermark: f64,
}

impl TierSpec {
    pub fn new(name: &str, capacity_bytes: u64) -> Self {
        TierSpec {
            name: name.to_string(),
            capacity_bytes,
            up: LinkSpec::unresolved(),
            wire_elem_bytes: 4.0,
            spill_watermark: 1.0,
        }
    }
}

/// The declarative tier chain, fastest (device) first.
///
/// The planner folds its transfer term over this chain: tokens resident at
/// or above `base` are covered by the per-step KV transfer coefficient the
/// cost model already carries, while a token fetched from a deeper tier
/// additionally crosses every wire between its rung and the base — the
/// per-token surcharge [`TierTopology::hop_factor`] expresses in units of
/// that coefficient.  Building a four-tier chain and planning over it:
///
/// ```
/// use kvpr::scheduler::{CostModel, PlanInput, Planner, SchedulePolicy, TierTopology};
/// // profiler → topology: capacities are config, wires are measured (here
/// // declared); the disk rung's unresolved link calibrates NVMe-shaped
/// let topo = TierTopology::standard(2 << 20, 64 << 20, 256 << 20)
///     .with_disk(1 << 30, 0.9)
///     .calibrated_bps(100e6, 30e-6);
/// assert_eq!(topo.len(), 4);
/// let disk = topo.tier_named("disk-nvme").unwrap();
/// assert!((topo.hop_factor(disk) - 4.0).abs() < 1e-9, "one extra NVMe hop");
///
/// // topology → plan: one entry point, however many hops the chain has
/// let cost = CostModel {
///     recompute_per_token_s: 2e-6,
///     transfer_kv_per_token_s: 1e-6,
///     transfer_act_per_token_s: 5e-7,
///     gpu_overhead_s: 0.0,
///     link_latency_s: 0.0,
/// };
/// let planner = Planner::new(cost, SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX)
///     .with_topology(topo);
/// let input = PlanInput::new(vec![128, 128]).prefix(disk, 64);
/// let plan = planner.plan_batch(&input);
/// assert_eq!(plan.l(), 64, "the disk prefix is cheaper to recompute than to two-hop");
/// assert!(plan.predicted_s <= plan.baseline_s);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct TierTopology {
    tiers: Vec<TierSpec>,
    /// Index of the deepest tier the planner's base KV transfer term
    /// already covers (cpu-dram in the canonical chain): fetching from any
    /// deeper tier pays the extra wires as a surcharge.
    base: usize,
}

impl TierTopology {
    /// A chain from explicit tier specs.  `base` is the index of the
    /// deepest tier the per-step transfer term reads from for free.
    pub fn new(tiers: Vec<TierSpec>, base: usize) -> Self {
        assert!(!tiers.is_empty(), "a topology needs at least one tier");
        assert!(base < tiers.len(), "base {base} out of range");
        TierTopology { tiers, base }
    }

    /// The minimal measured chain: a device tier over one host tier joined
    /// by the primary interconnect — what the profiler can see on its own.
    pub fn device_host(gpu_capacity_bytes: u64, link: LinkSpec) -> Self {
        let gpu = TierSpec::new("gpu-hbm", gpu_capacity_bytes);
        let mut host = TierSpec::new("cpu-dram", 0);
        host.up = link;
        TierTopology { tiers: vec![gpu, host], base: 1 }
    }

    /// The canonical three-tier serving chain gpu-hbm ⊃ pinned ⊃ cpu-dram
    /// with unresolved links (the serving loop calibrates them from the
    /// profiled engine wire).  A gpu capacity of 0 means "inherit" — the
    /// coordinator substitutes its KV budget.
    pub fn standard(gpu_bytes: u64, pinned_bytes: u64, dram_bytes: u64) -> Self {
        let tiers = vec![
            TierSpec::new("gpu-hbm", gpu_bytes),
            TierSpec::new("pinned", pinned_bytes),
            TierSpec::new("cpu-dram", dram_bytes),
        ];
        TierTopology { tiers, base: 2 }
    }

    /// Append an NVMe disk rung below the chain and set the watermark at
    /// which the rung above it starts spilling cold blocks down.  The disk
    /// link stays unresolved: calibration derives it NVMe-shaped from the
    /// wire above.  The new rung inherits the chain's current wire
    /// element width, so `with_wire_elem_bytes` composes in either order.
    pub fn with_disk(mut self, disk_bytes: u64, spill_watermark: f64) -> Self {
        let width = self.tiers.last().map_or(4.0, |t| t.wire_elem_bytes);
        if let Some(last) = self.tiers.last_mut() {
            last.spill_watermark = spill_watermark;
        }
        let mut disk = TierSpec::new("disk-nvme", disk_bytes);
        disk.wire_elem_bytes = width;
        self.tiers.push(disk);
        self
    }

    /// Append a **remote** rung below the chain: a sharded worker's hop to
    /// host tiers it does not own, declared with the interconnect the shard
    /// actually crosses (NVLink bridge, PCIe switch, RDMA fabric, ...).
    /// Structurally this is [`TierTopology::with_disk`] with a declared
    /// wire instead of an NVMe-shaped derivation — the planner prices the
    /// extra hop through the same [`TierTopology::hop_factor`] fold, so a
    /// remote worker is a data change, not a planner fork.
    ///
    /// ```
    /// use kvpr::scheduler::{LinkSpec, TierTopology};
    /// let remote = LinkSpec { bytes_per_sec: 50e6, latency_s: 50e-6 };
    /// let topo = TierTopology::standard(2 << 20, 64 << 20, 256 << 20)
    ///     .with_remote_hop(1 << 30, remote)
    ///     .calibrated_bps(100e6, 30e-6);
    /// let rung = topo.deep_tier().unwrap();
    /// assert_eq!(topo.tier(rung).name, "remote");
    /// assert!((topo.hop_factor(rung) - 2.0).abs() < 1e-9, "100e6 / 50e6");
    /// ```
    pub fn with_remote_hop(mut self, capacity_bytes: u64, link: LinkSpec) -> Self {
        let width = self.tiers.last().map_or(4.0, |t| t.wire_elem_bytes);
        let mut remote = TierSpec::new("remote", capacity_bytes);
        remote.up = link;
        remote.wire_elem_bytes = width;
        self.tiers.push(remote);
        self
    }

    /// Set every rung's migration wire width (4.0 plain f32, 0.625 under
    /// int4 wire quantization).
    pub fn with_wire_elem_bytes(mut self, wire_elem_bytes: f64) -> Self {
        assert!(wire_elem_bytes > 0.0, "wire_elem_bytes must be positive");
        for t in &mut self.tiers {
            t.wire_elem_bytes = wire_elem_bytes;
        }
        self
    }

    /// Override one tier's capacity (the coordinator resolves a
    /// zero-capacity top tier to its KV budget through this).
    pub fn set_capacity(&mut self, tier: usize, capacity_bytes: u64) {
        self.tiers[tier].capacity_bytes = capacity_bytes;
    }

    /// Resolve every unresolved link from the measured primary wire: tiers
    /// at or above the base rung get the primary spec verbatim; each
    /// deeper rung with an unspecified link gets an NVMe-shaped derivation
    /// of the (resolved) wire directly above it — the same shape
    /// [`LinkConfig::nvme_below`] uses, so cost models and the emulated
    /// wires can never drift apart.  Explicitly-specified links are kept.
    pub fn calibrated(&self, primary: &LinkSpec) -> TierTopology {
        let mut out = self.clone();
        let mut above = *primary;
        for (i, t) in out.tiers.iter_mut().enumerate().skip(1) {
            if !t.up.is_resolved() {
                t.up = if i <= self.base {
                    *primary
                } else {
                    LinkSpec {
                        bytes_per_sec: above.bytes_per_sec / NVME_BANDWIDTH_FACTOR,
                        latency_s: above.latency_s.max(1e-6) * NVME_BANDWIDTH_FACTOR,
                    }
                };
            }
            above = t.up;
        }
        out
    }

    /// [`TierTopology::calibrated`] from raw primary-wire numbers.
    pub fn calibrated_bps(&self, bytes_per_sec: f64, latency_s: f64) -> TierTopology {
        self.calibrated(&LinkSpec { bytes_per_sec, latency_s })
    }

    pub fn len(&self) -> usize {
        self.tiers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tiers.is_empty()
    }

    pub fn tiers(&self) -> &[TierSpec] {
        &self.tiers
    }

    pub fn tier(&self, i: usize) -> &TierSpec {
        &self.tiers[i]
    }

    /// Index of the deepest tier the base transfer term covers.
    pub fn base(&self) -> usize {
        self.base
    }

    /// Index of the tier called `name`, if the chain has one.
    pub fn tier_named(&self, name: &str) -> Option<usize> {
        self.tiers.iter().position(|t| t.name == name)
    }

    /// Index of the chain's deepest below-base rung — the tier whose
    /// fetches pay a hop surcharge (an NVMe disk, a sharded worker's
    /// remote hop, ...) — or `None` when the chain ends at the base.
    pub fn deep_tier(&self) -> Option<usize> {
        (self.tiers.len() > self.base + 1).then(|| self.tiers.len() - 1)
    }

    /// The wire element width migrations across the device boundary charge
    /// (builders keep the chain uniform; this reads the boundary rung).
    pub fn wire_elem_bytes(&self) -> f64 {
        self.tiers.get(1).map_or(4.0, |t| t.wire_elem_bytes)
    }

    /// Bandwidth of the primary interconnect — the wire crossing into the
    /// chain's top (device) tier.  Infinite for a single-tier chain or an
    /// unthrottled wire.
    pub fn primary_bytes_per_sec(&self) -> f64 {
        match self.tiers.get(1) {
            Some(t) if t.up.is_resolved() => t.up.bytes_per_sec,
            _ => f64::INFINITY,
        }
    }

    /// Extra interconnect-equivalents one token fetched from `tier` pays
    /// this step on top of the base transfer term: 0 at or above the base
    /// rung, and one `primary / link` ratio for every wire between `tier`
    /// and the base below it.  Non-finite ratios (unthrottled emulation)
    /// fall back to [`NVME_BANDWIDTH_FACTOR`] per hop, mirroring the
    /// serving loop's historical fallback.
    pub fn hop_factor(&self, tier: usize) -> f64 {
        assert!(tier < self.tiers.len(), "tier {tier} out of range");
        let primary = self.primary_bytes_per_sec();
        let mut factor = 0.0;
        for spec in self.tiers.iter().take(tier + 1).skip(self.base + 1) {
            let ratio = primary / spec.up.bytes_per_sec;
            factor += if ratio.is_finite() && ratio > 0.0 {
                ratio
            } else {
                NVME_BANDWIDTH_FACTOR
            };
        }
        factor
    }

    /// Convert predicted idle-link seconds into a grantable link-byte
    /// budget on the primary wire (saturating; an unthrottled wire absorbs
    /// everything).
    pub fn slack_bytes(&self, slack_s: f64) -> u64 {
        if slack_s.is_nan() || slack_s <= 0.0 {
            return 0;
        }
        let bps = self.primary_bytes_per_sec();
        if !bps.is_finite() {
            return u64::MAX;
        }
        let bytes = slack_s * bps;
        if bytes >= u64::MAX as f64 {
            u64::MAX
        } else {
            bytes as u64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pcie() -> LinkSpec {
        LinkSpec { bytes_per_sec: 100e6, latency_s: 30e-6 }
    }

    #[test]
    fn standard_chain_calibrates_host_rungs_to_the_primary_wire() {
        let topo = TierTopology::standard(1 << 20, 2 << 20, 4 << 20).calibrated(&pcie());
        assert_eq!(topo.len(), 3);
        assert_eq!(topo.base(), 2);
        for i in 1..topo.len() {
            assert_eq!(topo.tier(i).up, pcie(), "host rung {i} rides the primary wire");
        }
        assert_eq!(topo.primary_bytes_per_sec(), 100e6);
        assert_eq!(topo.hop_factor(0), 0.0);
        assert_eq!(topo.hop_factor(2), 0.0, "the base rung is covered by the transfer term");
    }

    #[test]
    fn disk_rung_derives_an_nvme_shaped_wire() {
        let topo = TierTopology::standard(0, 1 << 20, 4 << 20)
            .with_disk(1 << 30, 0.9)
            .calibrated(&pcie());
        let disk = topo.tier_named("disk-nvme").unwrap();
        assert_eq!(disk, 3);
        let up = topo.tier(disk).up;
        assert!((up.bytes_per_sec - 25e6).abs() < 1.0, "bw {up:?}");
        assert!(up.latency_s > pcie().latency_s);
        // the derivation matches LinkConfig::nvme_below exactly
        let nvme = LinkConfig::nvme_below(&pcie().to_link_config(64 << 10));
        assert!((up.bytes_per_sec - nvme.bytes_per_sec).abs() < 1e-9);
        assert!((up.latency_s - nvme.latency_s).abs() < 1e-15);
        // and the planner surcharge is the bandwidth gap
        assert!((topo.hop_factor(disk) - NVME_BANDWIDTH_FACTOR).abs() < 1e-9);
        // the watermark landed on the rung above the disk
        assert!((topo.tier(2).spill_watermark - 0.9).abs() < 1e-12);
        assert!(topo.tier(1).spill_watermark >= 1.0);
    }

    #[test]
    fn explicit_links_survive_calibration() {
        let mut spec = TierSpec::new("disk-nvme", 1 << 30);
        spec.up = LinkSpec { bytes_per_sec: 7e9, latency_s: 1e-4 };
        let topo = TierTopology::new(
            vec![
                TierSpec::new("gpu-hbm", 1 << 20),
                TierSpec::new("cpu-dram", 4 << 20),
                spec,
            ],
            1,
        )
        .calibrated(&LinkSpec { bytes_per_sec: 28e9, latency_s: 30e-6 });
        let disk = topo.tier_named("disk-nvme").unwrap();
        assert_eq!(topo.tier(disk).up.bytes_per_sec, 7e9, "declared wire kept");
        assert!((topo.hop_factor(disk) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn deeper_chains_accumulate_hop_factors() {
        // a five-tier chain: every rung below the base adds its own ratio
        let mut cold = TierSpec::new("cold-object", 1 << 40);
        cold.up = LinkSpec { bytes_per_sec: 5e6, latency_s: 1e-3 };
        let tiers = vec![
            TierSpec::new("gpu-hbm", 1 << 20),
            TierSpec::new("pinned", 2 << 20),
            TierSpec::new("cpu-dram", 4 << 20),
            TierSpec::new("disk-nvme", 1 << 30),
            cold,
        ];
        let topo = TierTopology::new(tiers, 2).calibrated(&pcie());
        let disk = topo.tier_named("disk-nvme").unwrap();
        let cold = topo.tier_named("cold-object").unwrap();
        assert!((topo.hop_factor(disk) - 4.0).abs() < 1e-9);
        // cold pays the NVMe hop plus its own 100e6/5e6 = 20× wire
        assert!((topo.hop_factor(cold) - 24.0).abs() < 1e-9);
    }

    #[test]
    fn unthrottled_wires_fall_back_to_the_nvme_shape_ratio() {
        let topo = TierTopology::standard(0, 1 << 20, 4 << 20)
            .with_disk(1 << 30, 0.9)
            .calibrated(&LinkSpec { bytes_per_sec: f64::INFINITY, latency_s: 0.0 });
        let disk = topo.tier_named("disk-nvme").unwrap();
        assert!(
            (topo.hop_factor(disk) - NVME_BANDWIDTH_FACTOR).abs() < 1e-9,
            "inf/inf must fall back to the shape ratio"
        );
        assert_eq!(topo.slack_bytes(0.5), u64::MAX, "unthrottled wire absorbs everything");
    }

    #[test]
    fn slack_bytes_converts_idle_seconds_on_the_primary_wire() {
        let topo = TierTopology::standard(0, 1 << 20, 4 << 20).calibrated(&pcie());
        assert_eq!(topo.slack_bytes(0.0), 0);
        assert_eq!(topo.slack_bytes(-1.0), 0);
        assert_eq!(topo.slack_bytes(f64::NAN), 0);
        assert_eq!(topo.slack_bytes(0.01), 1_000_000);
    }

    #[test]
    fn remote_hop_is_a_declared_below_base_rung() {
        let remote = LinkSpec { bytes_per_sec: 20e6, latency_s: 80e-6 };
        let topo = TierTopology::standard(0, 1 << 20, 4 << 20)
            .with_remote_hop(1 << 30, remote)
            .calibrated(&pcie());
        let rung = topo.deep_tier().expect("remote rung below the base");
        assert_eq!(rung, 3);
        assert_eq!(topo.tier(rung).name, "remote");
        assert_eq!(topo.tier(rung).up, remote, "declared shard wire survives calibration");
        // the planner surcharge is the declared bandwidth gap: 100e6/20e6
        assert!((topo.hop_factor(rung) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn deep_tier_names_the_deepest_below_base_rung() {
        assert_eq!(TierTopology::standard(0, 1, 2).deep_tier(), None, "chain ends at the base");
        let disk = TierTopology::standard(0, 1, 2).with_disk(3, 0.9);
        assert_eq!(disk.deep_tier(), disk.tier_named("disk-nvme"));
        let remote = TierTopology::standard(0, 1, 2)
            .with_remote_hop(3, LinkSpec { bytes_per_sec: 1e6, latency_s: 0.0 });
        assert_eq!(remote.deep_tier(), remote.tier_named("remote"));
    }

    #[test]
    fn wire_width_builder_applies_to_every_rung() {
        let topo = TierTopology::standard(0, 1, 2).with_disk(3, 0.5).with_wire_elem_bytes(0.625);
        assert_eq!(topo.wire_elem_bytes(), 0.625);
        assert!(topo.tiers().iter().all(|t| t.wire_elem_bytes == 0.625));
    }
}
