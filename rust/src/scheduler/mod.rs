//! The scheduler module (paper §3.2).
//!
//! Solves the one-variable integer linear program of Eq. (11) for the
//! optimal KV-cache split point `l` — the prefix whose KV the GPU
//! *recomputes* from activations while the link transfers the remainder —
//! and turns the solution into per-step execution plans for the row-by-row
//! and column-by-column schedules.

mod cost;
mod plan;
mod split;

pub use cost::CostModel;
pub use plan::{PathKind, Planner, StepPlan};
pub use split::{Split, SplitSolver};

/// Which schedule the engine runs (paper §3, "LLM inference scheduling").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulePolicy {
    /// Minimise latency: one batch at a time, all layers, weights resident
    /// when possible.  Eq. (10) without the activation-transfer term.
    RowByRow,
    /// Maximise throughput: weights offloaded and reused across a group of
    /// batches per layer.  Full Eq. (10).
    ColumnByColumn,
}

/// Compatibility alias used by the CLI.
pub type Scheduler = Planner;
