//! The scheduler module (paper §3.2).
//!
//! Solves the one-variable integer linear program of Eq. (11) for the
//! optimal KV-cache split point `l` — the prefix whose KV the GPU
//! *recomputes* from activations while the link transfers the remainder —
//! and turns the solution into per-step execution plans for the row-by-row
//! and column-by-column schedules.
//!
//! Planning is one stage of the automated pipeline **profiler → topology →
//! plan → runtime**:
//!
//! 1. the [`profiler`](crate::profiler) measures the wires and packages
//!    them as the root of a declarative [`TierTopology`]
//!    ([`SystemProfile::topology`](crate::profiler::SystemProfile::topology));
//! 2. configuration stacks capacities below the measured boundary and
//!    [`TierTopology::calibrated`] resolves the remaining links;
//! 3. the [`Planner`] — handed that chain via [`Planner::with_topology`] —
//!    answers every step with one entry point, [`Planner::plan_batch`],
//!    folding the transfer term over however many hops the chain declares
//!    (a [`PlanInput`] names the per-tier prefix spans; there is no
//!    per-hardware-shape planner fork);
//! 4. the runtime (the continuous serving loop) consumes the resulting
//!    [`StepPlan`] — the split `l` drives the decode step, and
//!    [`StepPlan::link_slack_bytes`] becomes the migration engine's
//!    per-step link-byte grant, so tier traffic soaks up exactly the idle
//!    wire time the plan predicts;
//! 5. in the **overlapped pipeline** the next step's solve runs on a stage
//!    worker while this step computes — [`PlanHandoff`] validity tokens
//!    (the exact [`PlanInput`] each plan was solved against) guarantee an
//!    adopted prebuilt plan is bit-identical to the inline solve it
//!    replaced, and anything stale falls back to a counted re-solve.

mod cost;
mod handoff;
mod plan;
mod split;
mod topology;

pub use cost::CostModel;
pub use handoff::{HandoffReport, PlanHandoff, PlanTicket, Redemption};
pub use plan::{PathKind, PlanInput, Planner, StepPlan, TierPrefix};
pub use split::{Split, SplitSolver};
pub use topology::{LinkSpec, TierSpec, TierTopology};

/// Which schedule the engine runs (paper §3, "LLM inference scheduling").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulePolicy {
    /// Minimise latency: one batch at a time, all layers, weights resident
    /// when possible.  Eq. (10) without the activation-transfer term.
    RowByRow,
    /// Maximise throughput: weights offloaded and reused across a group of
    /// batches per layer.  Full Eq. (10).
    ColumnByColumn,
}

/// Compatibility alias used by the CLI.
pub type Scheduler = Planner;
