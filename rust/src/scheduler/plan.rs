//! Per-step execution planning: adaptive re-solve of the LP as the sequence
//! grows (paper §3.2 "the optimal split point depends on the current
//! sequence length s', which increases during generation and must therefore
//! be determined adaptively"), quantised onto the static artifact buckets.

use super::{CostModel, SchedulePolicy, Split, SplitSolver};

/// Which artifact path a decode step takes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PathKind {
    /// `decode_full_*`: transfer the whole KV cache (l = 0).
    FullTransfer,
    /// `recompute_* + decode_merge_*`: KVPR split schedule.
    PartialRecompute { l: usize },
}

/// The plan for one decode step of one layer.
#[derive(Debug, Clone, PartialEq)]
pub struct StepPlan {
    pub path: PathKind,
    /// Continuous-LP optimum (before bucket quantisation) — logged so
    /// Fig 12 can be reproduced from engine traces too.
    pub ideal_l: usize,
    /// Predicted step time under the chosen (bucketed) path.
    pub predicted_s: f64,
    /// Predicted step time at l = 0.
    pub baseline_s: f64,
}

impl StepPlan {
    pub fn l(&self) -> usize {
        match self.path {
            PathKind::FullTransfer => 0,
            PathKind::PartialRecompute { l } => l,
        }
    }
}

/// Adaptive planner: owns the solver + the available L buckets.
#[derive(Debug, Clone)]
pub struct Planner {
    solver: SplitSolver,
    /// Static artifact split buckets (ascending), e.g. [32, 64, 96].
    buckets: Vec<usize>,
    /// Upper bound on l independent of s' (the paper's `l ≤ s` constraint
    /// when only prompt activations are retained; `usize::MAX` when the
    /// engine stores activations for generated tokens too).
    l_cap: usize,
}

impl Planner {
    pub fn new(cost: CostModel, policy: SchedulePolicy, buckets: Vec<usize>, l_cap: usize) -> Self {
        let mut buckets = buckets;
        buckets.sort_unstable();
        Planner { solver: SplitSolver::new(cost, policy), buckets, l_cap }
    }

    pub fn solver(&self) -> &SplitSolver {
        &self.solver
    }

    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// Continuous-grid solve (simulator; no bucket constraint).
    pub fn solve_exact(&self, s_prime: usize) -> Split {
        self.solver.solve(s_prime, self.l_cap.min(s_prime))
    }

    /// Plan one decode step: `kv_len` valid cached tokens (= s' here).
    pub fn plan_step(&self, kv_len: usize) -> StepPlan {
        let s_prime = kv_len;
        let ideal = self.solver.solve(s_prime, self.l_cap.min(s_prime));
        let l = self
            .solver
            .quantize_to_buckets(s_prime, &self.buckets, kv_len.min(self.l_cap));
        let path = if l == 0 {
            PathKind::FullTransfer
        } else {
            PathKind::PartialRecompute { l }
        };
        StepPlan {
            path,
            ideal_l: ideal.l,
            predicted_s: self.solver.objective(l, s_prime),
            baseline_s: self.solver.objective(0, s_prime),
        }
    }

    /// Plan one decode step for a **formed batch**: aggregate each member's
    /// cached-token count s'ᵢ into the Eq. (10)/(11) cost model and solve
    /// once for the whole batch (the continuous-batching coordinator calls
    /// this per batch per step).
    ///
    /// The aggregation is the paper's batch-scaling: marginal per-token
    /// costs grow linearly with the number of lanes, the shared split point
    /// is bounded by the *shortest* member (a prefix can only be recomputed
    /// where every lane has one), and the objective is evaluated at the
    /// longest member's s' (lanes are padded to a common length).
    ///
    /// ```
    /// use kvpr::scheduler::{CostModel, Planner, SchedulePolicy};
    /// let cost = CostModel {
    ///     recompute_per_token_s: 1e-6,
    ///     transfer_kv_per_token_s: 1e-6,
    ///     transfer_act_per_token_s: 5e-7,
    ///     gpu_overhead_s: 0.0,
    ///     link_latency_s: 0.0,
    /// };
    /// // per-lane cost model; the batch aggregation happens in plan_batch
    /// let planner = Planner::new(cost, SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX);
    /// let plan = planner.plan_batch(&[120, 120, 120, 120]);
    /// assert!(plan.l() > 0, "transfer-bound batch must recompute a prefix");
    /// assert!(plan.predicted_s <= plan.baseline_s);
    /// ```
    pub fn plan_batch(&self, lane_s_primes: &[usize]) -> StepPlan {
        self.plan_batch_tiered(lane_s_primes, 0, 0)
    }

    /// [`Planner::plan_batch`] for a group running over the tiered kvstore:
    ///
    /// * `resident` — tokens of the group's KV *suffix* already resident in
    ///   gpu-hbm.  They leave both the transfer and recompute terms, so the
    ///   plan is solved on the effective cached length `s' − resident`
    ///   (already-on-GPU blocks shrink the transfer term).  This must be
    ///   the **settled** suffix only: a block whose asynchronous demotion
    ///   is in flight released its gpu bytes at issuance, so the store
    ///   reports it non-resident from that instant
    ///   ([`KvStore::gpu_resident_tokens`](crate::kvstore::KvStore::gpu_resident_tokens))
    ///   and the plan re-pays its transfer immediately — never trust a
    ///   window the writeback is still vacating.
    /// * `l_floor` — tokens of the group's KV *prefix* whose stored KV the
    ///   store dropped (keeping X): the recompute path must cover them, so
    ///   `l = 0` and any bucket below the floor are infeasible.  When no
    ///   bucket at or above the floor fits, the plan degrades to full
    ///   transfer (the emulated store's drop is advisory accounting; the
    ///   host rows still exist).
    pub fn plan_batch_tiered(
        &self,
        lane_s_primes: &[usize],
        resident: usize,
        l_floor: usize,
    ) -> StepPlan {
        assert!(!lane_s_primes.is_empty(), "plan_batch over an empty batch");
        let n = lane_s_primes.len() as f64;
        let s_prime = lane_s_primes.iter().max().unwrap().saturating_sub(resident);
        let feasible = lane_s_primes.iter().min().unwrap().saturating_sub(resident);

        let mut cost = self.solver.cost.clone();
        cost.recompute_per_token_s *= n;
        cost.transfer_kv_per_token_s *= n;
        cost.transfer_act_per_token_s *= n;
        let solver = SplitSolver::new(cost, self.solver.policy);

        let l_max = self.l_cap.min(feasible);
        let ideal = solver.solve(s_prime, l_max);
        let l = solver.quantize_to_buckets_floor(s_prime, &self.buckets, l_max, l_floor);
        let path = if l == 0 {
            PathKind::FullTransfer
        } else {
            PathKind::PartialRecompute { l }
        };
        StepPlan {
            path,
            ideal_l: ideal.l,
            predicted_s: solver.objective(l, s_prime),
            baseline_s: solver.objective(0, s_prime),
        }
    }

    /// [`Planner::plan_batch_tiered`] for a group over the **four-tier**
    /// store: `disk_prefix` tokens of the group's KV live on the disk tier
    /// in the contiguous region *directly above* the dropped-KV floor —
    /// token positions `[l_floor, l_floor + disk_prefix)` — so fetching
    /// them this step is a *two-hop* transfer: an NVMe hop on top of the
    /// interconnect, costing `nvme_factor` extra interconnect-equivalents
    /// per token.  Two candidate splits are compared:
    ///
    /// * the three-tier optimum, paying the two-hop surcharge for every
    ///   disk token beyond its split, and
    /// * a split whose floor is raised to cover the whole disk region by
    ///   recompute (no disk byte crosses either wire),
    ///
    /// and the cheaper plan wins — the disk tier thus *pushes the split
    /// up*: prefixes too cold for dram become recompute work before they
    /// become NVMe reads.  `predicted_s`/`baseline_s` include the
    /// surcharge, so the serving metrics stay honest.
    pub fn plan_batch_four_tier(
        &self,
        lane_s_primes: &[usize],
        resident: usize,
        l_floor: usize,
        disk_prefix: usize,
        nvme_factor: f64,
    ) -> StepPlan {
        let a = self.plan_batch_tiered(lane_s_primes, resident, l_floor);
        if disk_prefix == 0 {
            return a;
        }
        let n = lane_s_primes.len() as f64;
        let extra = self.solver.cost.transfer_kv_per_token_s * nvme_factor.max(0.0) * n;
        // the disk region ends at l_floor + disk_prefix; a split of l
        // covers its tokens below l (and the floor region below l_floor
        // holds no stored KV at all, so it can never owe the surcharge —
        // relevant when an infeasible floor degrades the plan to l = 0)
        let disk_end = l_floor + disk_prefix;
        let surcharge = |l: usize| disk_end.saturating_sub(l.max(l_floor)) as f64 * extra;
        let b = self.plan_batch_tiered(lane_s_primes, resident, disk_end);
        let (plan, cost) = {
            let ca = a.predicted_s + surcharge(a.l());
            let cb = b.predicted_s + surcharge(b.l());
            if cb < ca {
                (b, cb)
            } else {
                (a, ca)
            }
        };
        let mut plan = plan;
        plan.baseline_s += surcharge(0);
        plan.predicted_s = cost;
        plan
    }

    /// The split-point trajectory over a whole generation (Fig 12): one
    /// continuous-optimum l* per generated token.
    pub fn split_trajectory(&self, prompt_len: usize, gen_len: usize) -> Vec<usize> {
        (0..gen_len)
            .map(|step| self.solve_exact(prompt_len + step).l)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HardwareConfig, ModelConfig};

    fn planner(policy: SchedulePolicy) -> Planner {
        let cost = CostModel::from_hardware(
            &HardwareConfig::a100_x16(),
            &ModelConfig::opt_6_7b(),
            32,
        );
        Planner::new(cost, policy, vec![32, 64, 96], usize::MAX)
    }

    #[test]
    fn plan_picks_partial_when_transfer_bound() {
        let p = planner(SchedulePolicy::RowByRow);
        let plan = p.plan_step(128);
        match plan.path {
            PathKind::PartialRecompute { l } => assert!([32, 64, 96].contains(&l)),
            PathKind::FullTransfer => panic!("expected partial recompute"),
        }
        assert!(plan.predicted_s <= plan.baseline_s);
    }

    #[test]
    fn plan_respects_prompt_cap() {
        let cost = CostModel::from_hardware(
            &HardwareConfig::a100_x16(),
            &ModelConfig::opt_6_7b(),
            32,
        );
        let p = Planner::new(cost, SchedulePolicy::RowByRow, vec![32, 64, 96], 40);
        let plan = p.plan_step(128);
        assert!(plan.l() <= 40);
    }

    #[test]
    fn trajectory_is_monotone_when_unclamped() {
        // As s' grows the transfer side grows, so l* grows (paper Fig 12's
        // rising trend once past the clamp).
        let p = planner(SchedulePolicy::RowByRow);
        let traj = p.split_trajectory(128, 32);
        assert_eq!(traj.len(), 32);
        for w in traj.windows(2) {
            assert!(w[1] >= w[0], "trajectory must not decrease: {traj:?}");
        }
    }

    #[test]
    fn trajectory_clamps_at_prompt_when_capped() {
        // Fig 12 with the paper's l ≤ s constraint: flat at s once l* ≥ s.
        let cost = CostModel {
            recompute_per_token_s: 1e-9, // recompute essentially free
            transfer_kv_per_token_s: 1e-6,
            transfer_act_per_token_s: 5e-7,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        let p = Planner::new(cost, SchedulePolicy::RowByRow, vec![], 128);
        let traj = p.split_trajectory(128, 32);
        assert!(traj.iter().all(|&l| l == 128), "{traj:?}");
    }

    #[test]
    fn batch_plan_matches_scaled_single_plan() {
        // n identical lanes through plan_batch == one lane through a planner
        // whose cost model was pre-scaled by n (the engine's construction)
        let base = CostModel::from_hardware(
            &HardwareConfig::a100_x16(),
            &ModelConfig::opt_6_7b(),
            1,
        );
        let per_lane = Planner::new(base.clone(), SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX);
        let scaled = CostModel::from_hardware(
            &HardwareConfig::a100_x16(),
            &ModelConfig::opt_6_7b(),
            32,
        );
        let pre_scaled = Planner::new(scaled, SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX);
        let batch_plan = per_lane.plan_batch(&[128; 32]);
        let single_plan = pre_scaled.plan_step(128);
        assert_eq!(batch_plan.l(), single_plan.l());
        assert!((batch_plan.predicted_s - single_plan.predicted_s).abs() < 1e-12);
    }

    #[test]
    fn batch_plan_bounded_by_shortest_member() {
        // a lane with only 40 cached tokens caps the shared split below 64
        let cost = CostModel {
            recompute_per_token_s: 1e-9, // recompute nearly free → wants max l
            transfer_kv_per_token_s: 1e-6,
            transfer_act_per_token_s: 5e-7,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        let p = Planner::new(cost, SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX);
        let plan = p.plan_batch(&[128, 128, 40, 128]);
        assert!(plan.l() <= 40, "split {} exceeds shortest member", plan.l());
        assert_eq!(plan.l(), 32);
    }

    #[test]
    fn resident_suffix_shrinks_the_plan() {
        let p = planner(SchedulePolicy::RowByRow);
        let full = p.plan_batch(&[128; 4]);
        let tiered = p.plan_batch_tiered(&[128; 4], 64, 0);
        // 64 resident tokens leave the transfer term: the step gets cheaper
        assert!(tiered.predicted_s < full.predicted_s);
        // and with (almost) everything resident there is nothing to split
        let all = p.plan_batch_tiered(&[128; 4], 120, 0);
        assert_eq!(all.path, PathKind::FullTransfer);
        assert!(all.predicted_s <= tiered.predicted_s);
    }

    #[test]
    fn shrinking_resident_repays_the_transfer_term() {
        // the coordinator contract for async demotions: when the store
        // revokes residency at eviction-issuance time, the very next plan
        // (smaller `resident`) must already charge the extra transfer —
        // the cost is monotone non-increasing in the settled suffix
        let p = planner(SchedulePolicy::RowByRow);
        let mut prev = f64::INFINITY;
        for resident in [0usize, 32, 64, 96] {
            let plan = p.plan_batch_tiered(&[128; 4], resident, 0);
            assert!(
                plan.predicted_s <= prev + 1e-15,
                "resident {resident}: {} > {}",
                plan.predicted_s,
                prev
            );
            prev = plan.predicted_s;
        }
    }

    #[test]
    fn resident_matches_shorter_sequence_plan() {
        // planning with r resident tokens ≡ planning the s'−r suffix
        let p = planner(SchedulePolicy::RowByRow);
        let a = p.plan_batch_tiered(&[128, 128], 32, 0);
        let b = p.plan_batch(&[96, 96]);
        assert_eq!(a.l(), b.l());
        assert!((a.predicted_s - b.predicted_s).abs() < 1e-12);
    }

    #[test]
    fn dropped_prefix_floors_the_split() {
        // recompute hopeless → the unconstrained plan is full transfer...
        let cost = CostModel {
            recompute_per_token_s: 1e-3,
            transfer_kv_per_token_s: 1e-9,
            transfer_act_per_token_s: 5e-10,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        let p = Planner::new(cost, SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX);
        assert_eq!(p.plan_batch(&[128; 2]).l(), 0);
        // ...but a 32-token dropped-KV prefix forces the recompute bucket
        let floored = p.plan_batch_tiered(&[128; 2], 0, 32);
        assert_eq!(floored.l(), 32);
        assert!(floored.predicted_s >= floored.baseline_s);
    }

    #[test]
    fn infeasible_floor_degrades_to_full_transfer() {
        let p = planner(SchedulePolicy::RowByRow);
        // floor above every feasible bucket (s' − resident < smallest bucket)
        let plan = p.plan_batch_tiered(&[40; 2], 20, 32);
        assert_eq!(plan.path, PathKind::FullTransfer);
    }

    #[test]
    fn plan_batch_is_the_untiered_special_case() {
        let p = planner(SchedulePolicy::RowByRow);
        for lanes in [vec![128usize; 4], vec![120, 64, 96, 128]] {
            let a = p.plan_batch(&lanes);
            let b = p.plan_batch_tiered(&lanes, 0, 0);
            assert_eq!(a.l(), b.l());
            assert_eq!(a.ideal_l, b.ideal_l);
            assert!((a.predicted_s - b.predicted_s).abs() < 1e-15);
        }
    }

    #[test]
    fn four_tier_reduces_to_tiered_without_disk() {
        let p = planner(SchedulePolicy::RowByRow);
        for lanes in [vec![128usize; 4], vec![120, 64, 96, 128]] {
            let a = p.plan_batch_tiered(&lanes, 32, 0);
            let b = p.plan_batch_four_tier(&lanes, 32, 0, 0, 4.0);
            assert_eq!(a.l(), b.l());
            assert!((a.predicted_s - b.predicted_s).abs() < 1e-15);
            assert!((a.baseline_s - b.baseline_s).abs() < 1e-15);
        }
    }

    #[test]
    fn disk_prefix_pays_the_two_hop_surcharge() {
        // recompute hopeless → the plan stays full transfer, but every
        // disk-prefix token now costs an extra NVMe hop on top of the
        // interconnect transfer the objective already charges
        let cost = CostModel {
            recompute_per_token_s: 1e-3,
            transfer_kv_per_token_s: 1e-9,
            transfer_act_per_token_s: 5e-10,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        let p = Planner::new(cost, SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX);
        let tiered = p.plan_batch_tiered(&[128; 2], 0, 0);
        assert_eq!(tiered.l(), 0);
        let four = p.plan_batch_four_tier(&[128; 2], 0, 0, 32, 4.0);
        assert_eq!(four.l(), 0, "covering by recompute is hopeless here");
        let surcharge = 32.0 * 1e-9 * 4.0 * 2.0; // tokens × C × nvme × lanes
        assert!((four.predicted_s - (tiered.predicted_s + surcharge)).abs() < 1e-15);
        assert!((four.baseline_s - (tiered.baseline_s + surcharge)).abs() < 1e-15);
    }

    #[test]
    fn expensive_disk_prefix_pushes_the_split_up() {
        // commensurate costs: the three-tier plan picks bucket 32, but a
        // 64-token disk prefix makes the two-hop read of tokens [32, 64)
        // dearer than recomputing the whole prefix — the four-tier plan
        // raises the split to the covering bucket
        let cost = CostModel {
            recompute_per_token_s: 2e-6,
            transfer_kv_per_token_s: 1e-6,
            transfer_act_per_token_s: 5e-7,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        let p = Planner::new(cost, SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX);
        let tiered = p.plan_batch_tiered(&[128; 2], 0, 0);
        assert_eq!(tiered.l(), 32, "three-tier optimum is the low bucket");
        let four = p.plan_batch_four_tier(&[128; 2], 0, 0, 64, 4.0);
        assert_eq!(four.l(), 64, "disk prefix must push the split to its covering bucket");
        // and it must genuinely beat paying the surcharge at l = 32
        let surcharge_at_32 = 32.0 * 1e-6 * 4.0 * 2.0;
        assert!(four.predicted_s < tiered.predicted_s + surcharge_at_32);
    }

    #[test]
    fn disk_region_is_offset_by_the_dropped_prefix() {
        // dropped [0, 32) + disk [32, 64): the three-tier candidate lands
        // on the floor bucket l = 32, which covers *none* of the disk
        // region — the surcharge must still charge all 32 disk tokens, so
        // raising the split to cover through token 64 wins
        let cost = CostModel {
            recompute_per_token_s: 2e-6,
            transfer_kv_per_token_s: 1e-6,
            transfer_act_per_token_s: 5e-7,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        let p = Planner::new(cost, SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX);
        let floored = p.plan_batch_tiered(&[128; 2], 0, 32);
        assert_eq!(floored.l(), 32);
        let four = p.plan_batch_four_tier(&[128; 2], 0, 32, 32, 4.0);
        assert_eq!(
            four.l(),
            64,
            "the covering split must reach the disk region's end, not its length"
        );
    }

    #[test]
    fn fulltransfer_when_no_feasible_bucket() {
        let p = planner(SchedulePolicy::RowByRow);
        // kv_len below the smallest bucket
        let plan = p.plan_step(16);
        assert_eq!(plan.path, PathKind::FullTransfer);
        assert_eq!(plan.l(), 0);
    }

    #[test]
    fn ideal_l_recorded() {
        let p = planner(SchedulePolicy::RowByRow);
        let plan = p.plan_step(128);
        assert!(plan.ideal_l > 0);
        assert!(plan.ideal_l <= 128);
    }
}
