//! Per-step execution planning: adaptive re-solve of the LP as the sequence
//! grows (paper §3.2 "the optimal split point depends on the current
//! sequence length s', which increases during generation and must therefore
//! be determined adaptively"), quantised onto the static artifact buckets.
//!
//! Planning is **topology-driven**: one entry point,
//! [`Planner::plan_batch`], takes a [`PlanInput`] describing the step —
//! per-lane cached lengths, the device-resident suffix, the dropped-prefix
//! floor, and the per-tier resident prefix spans — and folds the transfer
//! term over however many hops the planner's [`TierTopology`] declares.
//! The 3-tier and 4-tier closed forms the scheduler used to expose as
//! separate entry points are just 0- and 1-span instances of the same
//! fold (the test module keeps them alive as oracle transcriptions); a
//! deeper chain — a second storage rung, a sharded worker's remote hop —
//! is a data change, not a planner fork.

use super::topology::TierTopology;
use super::{CostModel, SchedulePolicy, Split, SplitSolver};

/// Which artifact path a decode step takes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PathKind {
    /// `decode_full_*`: transfer the whole KV cache (l = 0).
    FullTransfer,
    /// `recompute_* + decode_merge_*`: KVPR split schedule.
    PartialRecompute { l: usize },
}

/// The plan for one decode step of one layer.
#[derive(Debug, Clone, PartialEq)]
pub struct StepPlan {
    pub path: PathKind,
    /// Continuous-LP optimum (before bucket quantisation) — logged so
    /// Fig 12 can be reproduced from engine traces too.
    pub ideal_l: usize,
    /// Predicted step time under the chosen (bucketed) path.
    pub predicted_s: f64,
    /// Predicted step time at l = 0.
    pub baseline_s: f64,
    /// Predicted idle-link budget of this step, in bytes on the primary
    /// interconnect: the `baseline_s − predicted_s` seconds the split
    /// freed, converted at the topology's primary-wire bandwidth.  The
    /// serving loop grants exactly this much to the migration engine each
    /// step, so tier traffic soaks up the idle link time the plan predicts
    /// and nothing more.  0 when the plan saved nothing (full transfer
    /// keeps the wire busy end to end) or the planner has no topology.
    pub link_slack_bytes: u64,
}

impl StepPlan {
    pub fn l(&self) -> usize {
        match self.path {
            PathKind::FullTransfer => 0,
            PathKind::PartialRecompute { l } => l,
        }
    }

    /// A degenerate full-transfer plan (`l = 0`, no predicted win over the
    /// baseline) — the shape non-partial policies and handoff tests use.
    pub fn full(predicted_s: f64, link_slack_bytes: u64) -> Self {
        StepPlan {
            path: PathKind::FullTransfer,
            ideal_l: 0,
            predicted_s,
            baseline_s: predicted_s,
            link_slack_bytes,
        }
    }
}

/// A contiguous run of tokens resident on one topology tier, stacked
/// directly above the dropped-prefix floor (see [`PlanInput`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierPrefix {
    /// Index into the planner's [`TierTopology`] chain.
    pub tier: usize,
    /// Tokens of the span.
    pub tokens: usize,
}

/// Everything [`Planner::plan_batch`] needs to know about one step of one
/// decode group — the planner-facing summary of the tiered store's state.
///
/// Token layout, oldest first: `[0, l_floor)` dropped KV (recompute must
/// cover it), then `shared_prefix` tokens adopted from the prefix-sharing
/// registry (zero transfer — another request already paid for them), then
/// each [`TierPrefix`] span in order (tokens settled on deeper topology
/// tiers, paying [`TierTopology::hop_factor`] extra wire per token
/// fetched), then host-tier tokens (the base transfer term), and finally
/// `resident` tokens already on the device (they leave the transfer term
/// entirely).
#[derive(Debug, Clone, PartialEq)]
pub struct PlanInput {
    /// Cached-token count s'ᵢ of every lane in the decode bucket.
    pub lane_s_primes: Vec<usize>,
    /// Tokens of the group's settled device-resident KV *suffix*.
    pub resident: usize,
    /// Tokens of the group's dropped-KV *prefix* (the recompute floor).
    pub l_floor: usize,
    /// Tokens of the group's adopted shared *prefix* — blocks the
    /// cross-request registry holds, fetched for free.  The fold prices
    /// them as a span of factor −1, cancelling the base transfer term
    /// token for token, so the Eq. (11) split sees the reuse with no
    /// planner fork.
    pub shared_prefix: usize,
    /// Per-tier resident prefix spans stacked directly above the floor
    /// (and above the shared prefix, when there is one).
    pub tier_prefixes: Vec<TierPrefix>,
}

impl PlanInput {
    pub fn new(lane_s_primes: Vec<usize>) -> Self {
        PlanInput {
            lane_s_primes,
            resident: 0,
            l_floor: 0,
            shared_prefix: 0,
            tier_prefixes: Vec::new(),
        }
    }

    /// Tokens of the settled device-resident suffix.  This must be the
    /// **settled** suffix only: a block whose asynchronous demotion is in
    /// flight released its gpu bytes at issuance, so the store reports it
    /// non-resident from that instant and the plan re-pays its transfer
    /// immediately — never trust a window the writeback is still vacating.
    pub fn resident(mut self, tokens: usize) -> Self {
        self.resident = tokens;
        self
    }

    /// Tokens of the dropped-KV prefix: the recompute path must cover
    /// them, so `l = 0` and any bucket below the floor are infeasible.
    pub fn dropped_floor(mut self, tokens: usize) -> Self {
        self.l_floor = tokens;
        self
    }

    /// Append a span of `tokens` resident on topology tier `tier`,
    /// directly above the previous span (or the floor).
    pub fn prefix(mut self, tier: usize, tokens: usize) -> Self {
        self.tier_prefixes.push(TierPrefix { tier, tokens });
        self
    }

    /// Tokens adopted from the cross-request prefix-sharing registry:
    /// they transfer for free, so the plan discounts them from the
    /// baseline and from every uncovered split.
    pub fn shared_prefix(mut self, tokens: usize) -> Self {
        self.shared_prefix = tokens;
        self
    }
}

/// Adaptive planner: owns the solver, the available L buckets, and the
/// [`TierTopology`] its transfer fold runs over.
#[derive(Debug, Clone)]
pub struct Planner {
    solver: SplitSolver,
    /// Static artifact split buckets (ascending), e.g. [32, 64, 96].
    buckets: Vec<usize>,
    /// Upper bound on l independent of s' (the paper's `l ≤ s` constraint
    /// when only prompt activations are retained; `usize::MAX` when the
    /// engine stores activations for generated tokens too).
    l_cap: usize,
    /// The declared tier chain: resolves [`TierPrefix`] spans to per-token
    /// hop surcharges and converts plan slack into link bytes.  `None`
    /// plans simple device-host chains (no spans, no slack prediction).
    topology: Option<TierTopology>,
}

impl Planner {
    pub fn new(cost: CostModel, policy: SchedulePolicy, buckets: Vec<usize>, l_cap: usize) -> Self {
        let mut buckets = buckets;
        buckets.sort_unstable();
        Planner { solver: SplitSolver::new(cost, policy), buckets, l_cap, topology: None }
    }

    /// Attach the declarative tier chain the transfer fold runs over
    /// (typically [`SystemProfile::topology`](crate::profiler::SystemProfile::topology)
    /// extended with the configured capacities and calibrated against the
    /// measured primary wire).
    pub fn with_topology(mut self, topology: TierTopology) -> Self {
        self.topology = Some(topology);
        self
    }

    pub fn solver(&self) -> &SplitSolver {
        &self.solver
    }

    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    pub fn topology(&self) -> Option<&TierTopology> {
        self.topology.as_ref()
    }

    /// Predicted idle-link bytes for a (predicted, baseline) pair.
    fn slack_bytes(&self, predicted_s: f64, baseline_s: f64) -> u64 {
        self.topology
            .as_ref()
            .map_or(0, |t| t.slack_bytes(baseline_s - predicted_s))
    }

    /// Continuous-grid solve (simulator; no bucket constraint).
    pub fn solve_exact(&self, s_prime: usize) -> Split {
        self.solver.solve(s_prime, self.l_cap.min(s_prime))
    }

    /// Plan one decode step: `kv_len` valid cached tokens (= s' here).
    pub fn plan_step(&self, kv_len: usize) -> StepPlan {
        let s_prime = kv_len;
        let ideal = self.solver.solve(s_prime, self.l_cap.min(s_prime));
        let l = self
            .solver
            .quantize_to_buckets(s_prime, &self.buckets, kv_len.min(self.l_cap));
        let path = if l == 0 {
            PathKind::FullTransfer
        } else {
            PathKind::PartialRecompute { l }
        };
        let predicted_s = self.solver.objective(l, s_prime);
        let baseline_s = self.solver.objective(0, s_prime);
        StepPlan {
            path,
            ideal_l: ideal.l,
            predicted_s,
            baseline_s,
            link_slack_bytes: self.slack_bytes(predicted_s, baseline_s),
        }
    }

    /// Plan one decode step for a **formed batch** over the declared tier
    /// chain: aggregate each member's cached-token count s'ᵢ into the
    /// Eq. (10)/(11) cost model, fold the transfer term over the
    /// [`PlanInput`]'s per-tier prefix spans, and solve once for the whole
    /// batch (the continuous-batching coordinator calls this per group per
    /// step).
    ///
    /// The aggregation is the paper's batch-scaling: marginal per-token
    /// costs grow linearly with the number of lanes, the shared split
    /// point is bounded by the *shortest* member (a prefix can only be
    /// recomputed where every lane has one), and the objective is
    /// evaluated at the longest member's s' (lanes are padded to a common
    /// length).  The `resident` suffix leaves the transfer term, the
    /// `l_floor` dropped prefix floors the split, and every
    /// [`TierPrefix`] span charges its tokens the topology's extra-hop
    /// wire whenever the chosen split does not cover them — the fold also
    /// tries raising the floor to each span boundary, so a prefix too cold
    /// for the host tiers becomes recompute work before it becomes a deep
    /// read.  A `shared_prefix` runs the same fold in reverse: its span
    /// *refunds* the base transfer term for every uncovered token (the
    /// registry already holds those blocks), so the split is steered away
    /// from recomputing — or paying wire for — tokens another request
    /// already settled.
    ///
    /// ```
    /// use kvpr::scheduler::{CostModel, PlanInput, Planner, SchedulePolicy};
    /// let cost = CostModel {
    ///     recompute_per_token_s: 1e-6,
    ///     transfer_kv_per_token_s: 1e-6,
    ///     transfer_act_per_token_s: 5e-7,
    ///     gpu_overhead_s: 0.0,
    ///     link_latency_s: 0.0,
    /// };
    /// // per-lane cost model; the batch aggregation happens in plan_batch
    /// let planner = Planner::new(cost, SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX);
    /// let plan = planner.plan_batch(&PlanInput::new(vec![120, 120, 120, 120]));
    /// assert!(plan.l() > 0, "transfer-bound batch must recompute a prefix");
    /// assert!(plan.predicted_s <= plan.baseline_s);
    /// ```
    ///
    /// # Panics
    ///
    /// Panics when `input.tier_prefixes` is non-empty but no
    /// [`TierTopology`] was attached via [`Planner::with_topology`] — a
    /// prefix span names a tier of the chain, so there is no meaningful
    /// way to price it without one.  (Also panics on an empty
    /// `lane_s_primes`, like every batch entry point before it.)
    pub fn plan_batch(&self, input: &PlanInput) -> StepPlan {
        let mut spans: Vec<(f64, usize)> = Vec::with_capacity(input.tier_prefixes.len() + 1);
        if input.shared_prefix > 0 {
            // adopted shared-prefix tokens live in blocks another request
            // already paid for: a factor of −1 cancels the base transfer
            // term token for token, so fetching them is free.
            spans.push((-1.0, input.shared_prefix));
        }
        for p in &input.tier_prefixes {
            let topo = self
                .topology
                .as_ref()
                .expect("PlanInput has tier prefixes but the Planner has no TierTopology");
            spans.push((topo.hop_factor(p.tier).max(0.0), p.tokens));
        }
        self.plan_spans(&input.lane_s_primes, input.resident, input.l_floor, &spans)
    }

    /// The transfer fold behind [`Planner::plan_batch`], over spans whose
    /// hop factors are already resolved (extra interconnect-equivalents
    /// per token).
    fn plan_spans(
        &self,
        lane_s_primes: &[usize],
        resident: usize,
        l_floor: usize,
        spans: &[(f64, usize)],
    ) -> StepPlan {
        assert!(!lane_s_primes.is_empty(), "plan_batch over an empty batch");
        let n = lane_s_primes.len() as f64;
        let s_prime = lane_s_primes.iter().max().unwrap().saturating_sub(resident);
        let feasible = lane_s_primes.iter().min().unwrap().saturating_sub(resident);

        let mut cost = self.solver.cost.clone();
        cost.recompute_per_token_s *= n;
        cost.transfer_kv_per_token_s *= n;
        cost.transfer_act_per_token_s *= n;
        let solver = SplitSolver::new(cost, self.solver.policy);

        let l_max = self.l_cap.min(feasible);
        let ideal = solver.solve(s_prime, l_max);

        // a span's tokens beyond the chosen split cross every extra wire
        // between their tier and the base rung this step; tokens the split
        // covers are rebuilt by recompute and never touch a deep wire.
        // (the floor region below l_floor holds no stored KV at all, so it
        // can never owe a surcharge — relevant when an infeasible floor
        // degrades the plan to l = 0)
        let surcharge = |l: usize| {
            let mut start = l_floor;
            let mut total = 0.0;
            for &(factor, tokens) in spans {
                let end = start + tokens;
                let extra = self.solver.cost.transfer_kv_per_token_s * factor * n;
                total += end.saturating_sub(l.max(start)) as f64 * extra;
                start = end;
            }
            total
        };

        let quantize =
            |floor: usize| solver.quantize_to_buckets_floor(s_prime, &self.buckets, l_max, floor);

        // candidate floors: the declared floor, plus — for every span — a
        // floor raised to the span's end, so the whole region below it is
        // covered by recompute and no deep byte crosses any wire.  The
        // cheapest candidate (objective + surcharge) wins; ties keep the
        // lower floor.
        let (l, predicted_s) = if spans.iter().all(|&(_, tokens)| tokens == 0) {
            let l = quantize(l_floor);
            (l, solver.objective(l, s_prime))
        } else {
            let mut floors = vec![l_floor];
            let mut end = l_floor;
            for &(factor, tokens) in spans {
                end += tokens;
                // raising the split to a span's end only pays off when
                // fetching the span costs extra wire; a negative-factor
                // (shared) span is free to fetch, so covering it with
                // recompute is never a win.
                if tokens > 0 && factor > 0.0 {
                    floors.push(end);
                }
            }
            let mut best: Option<(usize, f64)> = None;
            let mut consider = |l: usize, cost: f64| match best {
                Some((_, c)) if cost >= c => {}
                _ => best = Some((l, cost)),
            };
            for &floor in &floors {
                let l = quantize(floor);
                consider(l, solver.objective(l, s_prime) + surcharge(l));
            }
            // a shared span *discounts* uncovered tokens, which the
            // objective-only bucket choice inside `quantize` cannot see:
            // give l = 0 (the maximal discount) a seat whenever the floor
            // allows it.
            if l_floor == 0 && spans.iter().any(|&(factor, tokens)| factor < 0.0 && tokens > 0) {
                consider(0, solver.objective(0, s_prime) + surcharge(0));
            }
            best.expect("at least the declared floor is a candidate")
        };
        let baseline_s = if spans.iter().all(|&(_, tokens)| tokens == 0) {
            solver.objective(0, s_prime)
        } else {
            solver.objective(0, s_prime) + surcharge(0)
        };

        let path = if l == 0 {
            PathKind::FullTransfer
        } else {
            PathKind::PartialRecompute { l }
        };
        StepPlan {
            path,
            ideal_l: ideal.l,
            predicted_s,
            baseline_s,
            link_slack_bytes: self.slack_bytes(predicted_s, baseline_s),
        }
    }

    /// The split-point trajectory over a whole generation (Fig 12): one
    /// continuous-optimum l* per generated token.
    pub fn split_trajectory(&self, prompt_len: usize, gen_len: usize) -> Vec<usize> {
        (0..gen_len)
            .map(|step| self.solve_exact(prompt_len + step).l)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HardwareConfig, ModelConfig};
    use crate::scheduler::topology::{LinkSpec, TierSpec};
    use crate::util::prng::{check_property, prop_cases, Prng};

    fn planner(policy: SchedulePolicy) -> Planner {
        let cost = CostModel::from_hardware(
            &HardwareConfig::a100_x16(),
            &ModelConfig::opt_6_7b(),
            32,
        );
        Planner::new(cost, policy, vec![32, 64, 96], usize::MAX)
    }

    /// A four-tier chain whose disk rung costs exactly `nvme_factor` extra
    /// interconnect-equivalents per token: the primary wire moves
    /// `nvme_factor` bytes/s and the disk wire 1 byte/s, so the
    /// `hop_factor` ratio is the factor itself, bit-for-bit.
    fn four_tier_topology(nvme_factor: f64) -> TierTopology {
        let primary = LinkSpec { bytes_per_sec: nvme_factor, latency_s: 0.0 };
        let mut pinned = TierSpec::new("pinned", 1 << 20);
        pinned.up = primary;
        let mut dram = TierSpec::new("cpu-dram", 1 << 20);
        dram.up = primary;
        let mut disk = TierSpec::new("disk-nvme", 1 << 30);
        disk.up = LinkSpec { bytes_per_sec: 1.0, latency_s: 0.0 };
        TierTopology::new(
            vec![TierSpec::new("gpu-hbm", 1 << 20), pinned, dram, disk],
            2,
        )
    }

    fn four_tier_planner(policy: SchedulePolicy, nvme_factor: f64) -> (Planner, usize) {
        let topo = four_tier_topology(nvme_factor);
        let disk = topo.tier_named("disk-nvme").unwrap();
        (planner(policy).with_topology(topo), disk)
    }

    #[test]
    fn plan_picks_partial_when_transfer_bound() {
        let p = planner(SchedulePolicy::RowByRow);
        let plan = p.plan_step(128);
        match plan.path {
            PathKind::PartialRecompute { l } => assert!([32, 64, 96].contains(&l)),
            PathKind::FullTransfer => panic!("expected partial recompute"),
        }
        assert!(plan.predicted_s <= plan.baseline_s);
    }

    #[test]
    fn plan_respects_prompt_cap() {
        let cost = CostModel::from_hardware(
            &HardwareConfig::a100_x16(),
            &ModelConfig::opt_6_7b(),
            32,
        );
        let p = Planner::new(cost, SchedulePolicy::RowByRow, vec![32, 64, 96], 40);
        let plan = p.plan_step(128);
        assert!(plan.l() <= 40);
    }

    #[test]
    fn trajectory_is_monotone_when_unclamped() {
        // As s' grows the transfer side grows, so l* grows (paper Fig 12's
        // rising trend once past the clamp).
        let p = planner(SchedulePolicy::RowByRow);
        let traj = p.split_trajectory(128, 32);
        assert_eq!(traj.len(), 32);
        for w in traj.windows(2) {
            assert!(w[1] >= w[0], "trajectory must not decrease: {traj:?}");
        }
    }

    #[test]
    fn trajectory_clamps_at_prompt_when_capped() {
        // Fig 12 with the paper's l ≤ s constraint: flat at s once l* ≥ s.
        let cost = CostModel {
            recompute_per_token_s: 1e-9, // recompute essentially free
            transfer_kv_per_token_s: 1e-6,
            transfer_act_per_token_s: 5e-7,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        let p = Planner::new(cost, SchedulePolicy::RowByRow, vec![], 128);
        let traj = p.split_trajectory(128, 32);
        assert!(traj.iter().all(|&l| l == 128), "{traj:?}");
    }

    #[test]
    fn batch_plan_matches_scaled_single_plan() {
        // n identical lanes through plan_batch == one lane through a planner
        // whose cost model was pre-scaled by n (the engine's construction)
        let base = CostModel::from_hardware(
            &HardwareConfig::a100_x16(),
            &ModelConfig::opt_6_7b(),
            1,
        );
        let per_lane = Planner::new(base.clone(), SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX);
        let scaled = CostModel::from_hardware(
            &HardwareConfig::a100_x16(),
            &ModelConfig::opt_6_7b(),
            32,
        );
        let pre_scaled = Planner::new(scaled, SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX);
        let batch_plan = per_lane.plan_batch(&PlanInput::new(vec![128; 32]));
        let single_plan = pre_scaled.plan_step(128);
        assert_eq!(batch_plan.l(), single_plan.l());
        assert!((batch_plan.predicted_s - single_plan.predicted_s).abs() < 1e-12);
    }

    #[test]
    fn batch_plan_bounded_by_shortest_member() {
        // a lane with only 40 cached tokens caps the shared split below 64
        let cost = CostModel {
            recompute_per_token_s: 1e-9, // recompute nearly free → wants max l
            transfer_kv_per_token_s: 1e-6,
            transfer_act_per_token_s: 5e-7,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        let p = Planner::new(cost, SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX);
        let plan = p.plan_batch(&PlanInput::new(vec![128, 128, 40, 128]));
        assert!(plan.l() <= 40, "split {} exceeds shortest member", plan.l());
        assert_eq!(plan.l(), 32);
    }

    #[test]
    fn resident_suffix_shrinks_the_plan() {
        let p = planner(SchedulePolicy::RowByRow);
        let full = p.plan_batch(&PlanInput::new(vec![128; 4]));
        let tiered = p.plan_batch(&PlanInput::new(vec![128; 4]).resident(64));
        // 64 resident tokens leave the transfer term: the step gets cheaper
        assert!(tiered.predicted_s < full.predicted_s);
        // and with (almost) everything resident there is nothing to split
        let all = p.plan_batch(&PlanInput::new(vec![128; 4]).resident(120));
        assert_eq!(all.path, PathKind::FullTransfer);
        assert!(all.predicted_s <= tiered.predicted_s);
    }

    #[test]
    fn shrinking_resident_repays_the_transfer_term() {
        // the coordinator contract for async demotions: when the store
        // revokes residency at eviction-issuance time, the very next plan
        // (smaller `resident`) must already charge the extra transfer —
        // the cost is monotone non-increasing in the settled suffix
        let p = planner(SchedulePolicy::RowByRow);
        let mut prev = f64::INFINITY;
        for resident in [0usize, 32, 64, 96] {
            let plan = p.plan_batch(&PlanInput::new(vec![128; 4]).resident(resident));
            assert!(
                plan.predicted_s <= prev + 1e-15,
                "resident {resident}: {} > {}",
                plan.predicted_s,
                prev
            );
            prev = plan.predicted_s;
        }
    }

    #[test]
    fn resident_matches_shorter_sequence_plan() {
        // planning with r resident tokens ≡ planning the s'−r suffix
        let p = planner(SchedulePolicy::RowByRow);
        let a = p.plan_batch(&PlanInput::new(vec![128, 128]).resident(32));
        let b = p.plan_batch(&PlanInput::new(vec![96, 96]));
        assert_eq!(a.l(), b.l());
        assert!((a.predicted_s - b.predicted_s).abs() < 1e-12);
    }

    #[test]
    fn dropped_prefix_floors_the_split() {
        // recompute hopeless → the unconstrained plan is full transfer...
        let cost = CostModel {
            recompute_per_token_s: 1e-3,
            transfer_kv_per_token_s: 1e-9,
            transfer_act_per_token_s: 5e-10,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        let p = Planner::new(cost, SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX);
        assert_eq!(p.plan_batch(&PlanInput::new(vec![128; 2])).l(), 0);
        // ...but a 32-token dropped-KV prefix forces the recompute bucket
        let floored = p.plan_batch(&PlanInput::new(vec![128; 2]).dropped_floor(32));
        assert_eq!(floored.l(), 32);
        assert!(floored.predicted_s >= floored.baseline_s);
    }

    #[test]
    fn infeasible_floor_degrades_to_full_transfer() {
        let p = planner(SchedulePolicy::RowByRow);
        // floor above every feasible bucket (s' − resident < smallest bucket)
        let plan = p.plan_batch(&PlanInput::new(vec![40; 2]).resident(20).dropped_floor(32));
        assert_eq!(plan.path, PathKind::FullTransfer);
    }

    #[test]
    fn plain_input_is_the_untiered_special_case() {
        let p = planner(SchedulePolicy::RowByRow);
        for lanes in [vec![128usize; 4], vec![120, 64, 96, 128]] {
            let a = p.plan_batch(&PlanInput::new(lanes.clone()));
            let b = p.plan_batch(&PlanInput::new(lanes).resident(0).dropped_floor(0));
            assert_eq!(a.l(), b.l());
            assert_eq!(a.ideal_l, b.ideal_l);
            assert!((a.predicted_s - b.predicted_s).abs() < 1e-15);
        }
    }

    #[test]
    fn empty_prefix_span_reduces_to_the_spanless_plan() {
        let (p, disk) = four_tier_planner(SchedulePolicy::RowByRow, 4.0);
        for lanes in [vec![128usize; 4], vec![120, 64, 96, 128]] {
            let a = p.plan_batch(&PlanInput::new(lanes.clone()).resident(32));
            let b = p.plan_batch(&PlanInput::new(lanes).resident(32).prefix(disk, 0));
            assert_eq!(a.l(), b.l());
            assert!((a.predicted_s - b.predicted_s).abs() < 1e-15);
            assert!((a.baseline_s - b.baseline_s).abs() < 1e-15);
        }
    }

    #[test]
    fn disk_prefix_pays_the_two_hop_surcharge() {
        // recompute hopeless → the plan stays full transfer, but every
        // disk-prefix token now costs an extra NVMe hop on top of the
        // interconnect transfer the objective already charges
        let cost = CostModel {
            recompute_per_token_s: 1e-3,
            transfer_kv_per_token_s: 1e-9,
            transfer_act_per_token_s: 5e-10,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        let topo = four_tier_topology(4.0);
        let disk = topo.tier_named("disk-nvme").unwrap();
        let p = Planner::new(cost, SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX)
            .with_topology(topo);
        let tiered = p.plan_batch(&PlanInput::new(vec![128; 2]));
        assert_eq!(tiered.l(), 0);
        let four = p.plan_batch(&PlanInput::new(vec![128; 2]).prefix(disk, 32));
        assert_eq!(four.l(), 0, "covering by recompute is hopeless here");
        let surcharge = 32.0 * 1e-9 * 4.0 * 2.0; // tokens × C × nvme × lanes
        assert!((four.predicted_s - (tiered.predicted_s + surcharge)).abs() < 1e-15);
        assert!((four.baseline_s - (tiered.baseline_s + surcharge)).abs() < 1e-15);
    }

    #[test]
    fn expensive_disk_prefix_pushes_the_split_up() {
        // commensurate costs: the three-tier plan picks bucket 32, but a
        // 64-token disk prefix makes the two-hop read of tokens [32, 64)
        // dearer than recomputing the whole prefix — the fold raises the
        // split to the covering bucket
        let cost = CostModel {
            recompute_per_token_s: 2e-6,
            transfer_kv_per_token_s: 1e-6,
            transfer_act_per_token_s: 5e-7,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        let topo = four_tier_topology(4.0);
        let disk = topo.tier_named("disk-nvme").unwrap();
        let p = Planner::new(cost, SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX)
            .with_topology(topo);
        let tiered = p.plan_batch(&PlanInput::new(vec![128; 2]));
        assert_eq!(tiered.l(), 32, "three-tier optimum is the low bucket");
        let four = p.plan_batch(&PlanInput::new(vec![128; 2]).prefix(disk, 64));
        assert_eq!(four.l(), 64, "disk prefix must push the split to its covering bucket");
        // and it must genuinely beat paying the surcharge at l = 32
        let surcharge_at_32 = 32.0 * 1e-6 * 4.0 * 2.0;
        assert!(four.predicted_s < tiered.predicted_s + surcharge_at_32);
    }

    #[test]
    fn disk_region_is_offset_by_the_dropped_prefix() {
        // dropped [0, 32) + disk [32, 64): the three-tier candidate lands
        // on the floor bucket l = 32, which covers *none* of the disk
        // region — the surcharge must still charge all 32 disk tokens, so
        // raising the split to cover through token 64 wins
        let cost = CostModel {
            recompute_per_token_s: 2e-6,
            transfer_kv_per_token_s: 1e-6,
            transfer_act_per_token_s: 5e-7,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        let topo = four_tier_topology(4.0);
        let disk = topo.tier_named("disk-nvme").unwrap();
        let p = Planner::new(cost, SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX)
            .with_topology(topo);
        let floored = p.plan_batch(&PlanInput::new(vec![128; 2]).dropped_floor(32));
        assert_eq!(floored.l(), 32);
        let four =
            p.plan_batch(&PlanInput::new(vec![128; 2]).dropped_floor(32).prefix(disk, 32));
        assert_eq!(
            four.l(),
            64,
            "the covering split must reach the disk region's end, not its length"
        );
    }

    #[test]
    fn two_stacked_spans_fold_both_wires() {
        // a five-tier-style input: a deep span (factor 8) under a shallow
        // one (factor 4).  With recompute hopeless the plan stays full
        // transfer and owes both spans their own wire surcharges.
        let cost = CostModel {
            recompute_per_token_s: 1e-3,
            transfer_kv_per_token_s: 1e-9,
            transfer_act_per_token_s: 5e-10,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        // primary 8 B/s over wires of 1 and 2 B/s: factors 8 and 4
        let primary = LinkSpec { bytes_per_sec: 8.0, latency_s: 0.0 };
        let mut dram = TierSpec::new("cpu-dram", 1 << 20);
        dram.up = primary;
        let mut disk = TierSpec::new("disk-nvme", 1 << 30);
        disk.up = LinkSpec { bytes_per_sec: 2.0, latency_s: 0.0 };
        let mut cold = TierSpec::new("cold-object", 1 << 30);
        cold.up = LinkSpec { bytes_per_sec: 2.0, latency_s: 0.0 };
        let topo = TierTopology::new(
            vec![TierSpec::new("gpu-hbm", 1 << 20), dram, disk, cold],
            1,
        );
        let disk_i = topo.tier_named("disk-nvme").unwrap();
        let cold_i = topo.tier_named("cold-object").unwrap();
        assert_eq!(topo.hop_factor(disk_i), 4.0);
        assert_eq!(topo.hop_factor(cold_i), 8.0);
        let p = Planner::new(cost, SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX)
            .with_topology(topo);
        let plain = p.plan_batch(&PlanInput::new(vec![128; 2]));
        let deep = p.plan_batch(
            &PlanInput::new(vec![128; 2]).prefix(cold_i, 32).prefix(disk_i, 32),
        );
        assert_eq!(deep.l(), 0);
        // 32 tokens × 8× + 32 tokens × 4× across 2 lanes at C = 1e-9
        let surcharge = 32.0 * 1e-9 * 8.0 * 2.0 + 32.0 * 1e-9 * 4.0 * 2.0;
        assert!((deep.predicted_s - (plain.predicted_s + surcharge)).abs() < 1e-15);
        assert!((deep.baseline_s - (plain.baseline_s + surcharge)).abs() < 1e-15);
    }

    #[test]
    fn shared_prefix_is_priced_at_zero_transfer() {
        // recompute hopeless → full transfer either way, but every adopted
        // shared-prefix token refunds the base transfer term: the plan and
        // the baseline both drop by tokens × C × lanes.  No topology is
        // needed — the shared span's factor is a constant, not a hop.
        let cost = CostModel {
            recompute_per_token_s: 1e-3,
            transfer_kv_per_token_s: 1e-9,
            transfer_act_per_token_s: 5e-10,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        let p = Planner::new(cost, SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX);
        let plain = p.plan_batch(&PlanInput::new(vec![128; 2]));
        assert_eq!(plain.l(), 0);
        let shared = p.plan_batch(&PlanInput::new(vec![128; 2]).shared_prefix(32));
        assert_eq!(shared.l(), 0, "free tokens never justify recompute");
        let discount = 32.0 * 1e-9 * 2.0; // tokens × C × lanes
        assert!((shared.predicted_s - (plain.predicted_s - discount)).abs() < 1e-15);
        assert!((shared.baseline_s - (plain.baseline_s - discount)).abs() < 1e-15);
    }

    #[test]
    fn shared_prefix_stacks_under_a_disk_span() {
        // shared [0, 32) refunded, disk [32, 64) surcharged: the two spans
        // fold independently around the same split
        let cost = CostModel {
            recompute_per_token_s: 1e-3,
            transfer_kv_per_token_s: 1e-9,
            transfer_act_per_token_s: 5e-10,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        let topo = four_tier_topology(4.0);
        let disk = topo.tier_named("disk-nvme").unwrap();
        let p = Planner::new(cost, SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX)
            .with_topology(topo);
        let plain = p.plan_batch(&PlanInput::new(vec![128; 2]));
        let mixed =
            p.plan_batch(&PlanInput::new(vec![128; 2]).shared_prefix(32).prefix(disk, 32));
        assert_eq!(mixed.l(), 0);
        let delta = 32.0 * 1e-9 * 4.0 * 2.0 - 32.0 * 1e-9 * 2.0; // disk hop − shared refund
        assert!((mixed.predicted_s - (plain.predicted_s + delta)).abs() < 1e-15);
        assert!((mixed.baseline_s - (plain.baseline_s + delta)).abs() < 1e-15);
    }

    #[test]
    fn shared_prefix_never_costs_and_zero_reduces_to_spanless() {
        // commensurate costs: the plain plan recomputes a prefix the shared
        // span now makes free to fetch — the plan may keep or shrink the
        // split, but sharing can never make the step slower.  And a zero
        // shared prefix must reproduce the spanless plan bit for bit.
        let cost = CostModel {
            recompute_per_token_s: 2e-6,
            transfer_kv_per_token_s: 1e-6,
            transfer_act_per_token_s: 5e-7,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        let p = Planner::new(cost, SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX);
        let plain = p.plan_batch(&PlanInput::new(vec![128; 2]));
        assert_eq!(plain.l(), 32, "commensurate costs pick the low bucket");
        let shared = p.plan_batch(&PlanInput::new(vec![128; 2]).shared_prefix(64));
        assert!(shared.l() <= plain.l(), "free tokens never push the split up");
        assert!(shared.predicted_s <= plain.predicted_s);
        assert!(shared.predicted_s <= shared.baseline_s, "l = 0 is always a candidate");
        let zero = p.plan_batch(&PlanInput::new(vec![128; 2]).shared_prefix(0));
        assert_eq!(zero.l(), plain.l());
        assert!((zero.predicted_s - plain.predicted_s).abs() < 1e-15);
        assert!((zero.baseline_s - plain.baseline_s).abs() < 1e-15);
    }

    #[test]
    fn slack_prediction_tracks_the_split_savings() {
        // a topology-attached planner converts baseline − predicted into
        // primary-wire bytes; without a topology the field stays 0
        let bare = planner(SchedulePolicy::RowByRow);
        assert_eq!(bare.plan_batch(&PlanInput::new(vec![128; 4])).link_slack_bytes, 0);
        let topo = TierTopology::standard(0, 1 << 20, 4 << 20).calibrated_bps(100e6, 30e-6);
        let p = planner(SchedulePolicy::RowByRow).with_topology(topo);
        let plan = p.plan_batch(&PlanInput::new(vec![128; 4]));
        assert!(plan.predicted_s < plan.baseline_s, "transfer-bound batch must split");
        let want = ((plan.baseline_s - plan.predicted_s) * 100e6) as u64;
        assert_eq!(plan.link_slack_bytes, want);
        assert!(plan.link_slack_bytes > 0);
        // a forced full-transfer plan saves nothing: zero slack
        let cost = CostModel {
            recompute_per_token_s: 1e-3,
            transfer_kv_per_token_s: 1e-9,
            transfer_act_per_token_s: 5e-10,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        let topo = TierTopology::standard(0, 1 << 20, 4 << 20).calibrated_bps(100e6, 30e-6);
        let p = Planner::new(cost, SchedulePolicy::RowByRow, vec![32, 64, 96], usize::MAX)
            .with_topology(topo);
        let plan = p.plan_batch(&PlanInput::new(vec![128; 2]));
        assert_eq!(plan.path, PathKind::FullTransfer);
        assert_eq!(plan.link_slack_bytes, 0);
    }

    #[test]
    fn fulltransfer_when_no_feasible_bucket() {
        let p = planner(SchedulePolicy::RowByRow);
        // kv_len below the smallest bucket
        let plan = p.plan_step(16);
        assert_eq!(plan.path, PathKind::FullTransfer);
        assert_eq!(plan.l(), 0);
    }

    #[test]
    fn ideal_l_recorded() {
        let p = planner(SchedulePolicy::RowByRow);
        let plan = p.plan_step(128);
        assert!(plan.ideal_l > 0);
        assert!(plan.ideal_l <= 128);
    }

    // -- plan equivalence: the topology fold vs the legacy closed forms ----
    //
    // The three legacy entry points the scheduler once exposed (bare-lane,
    // 3-tier, 4-tier closed forms — since deleted) are preserved below as
    // standalone oracle transcriptions of their pre-topology bodies.  The
    // property pins the single topology-driven `plan_batch` to reproduce
    // every one of them bit-for-bit when given the equivalent 2/3/4-tier
    // topologies, so the fold can never silently drift from the paper's
    // closed forms.

    fn oracle_tiered(
        p: &Planner,
        lanes: &[usize],
        resident: usize,
        l_floor: usize,
    ) -> (usize, usize, f64, f64) {
        let n = lanes.len() as f64;
        let s_prime = lanes.iter().max().unwrap().saturating_sub(resident);
        let feasible = lanes.iter().min().unwrap().saturating_sub(resident);
        let mut cost = p.solver.cost.clone();
        cost.recompute_per_token_s *= n;
        cost.transfer_kv_per_token_s *= n;
        cost.transfer_act_per_token_s *= n;
        let solver = SplitSolver::new(cost, p.solver.policy);
        let l_max = p.l_cap.min(feasible);
        let ideal = solver.solve(s_prime, l_max);
        let l = solver.quantize_to_buckets_floor(s_prime, &p.buckets, l_max, l_floor);
        (
            l,
            ideal.l,
            solver.objective(l, s_prime),
            solver.objective(0, s_prime),
        )
    }

    fn oracle_four_tier(
        p: &Planner,
        lanes: &[usize],
        resident: usize,
        l_floor: usize,
        disk_prefix: usize,
        nvme_factor: f64,
    ) -> (usize, usize, f64, f64) {
        let a = oracle_tiered(p, lanes, resident, l_floor);
        if disk_prefix == 0 {
            return a;
        }
        let n = lanes.len() as f64;
        let extra = p.solver.cost.transfer_kv_per_token_s * nvme_factor.max(0.0) * n;
        let disk_end = l_floor + disk_prefix;
        let surcharge = |l: usize| disk_end.saturating_sub(l.max(l_floor)) as f64 * extra;
        let b = oracle_tiered(p, lanes, resident, disk_end);
        let ca = a.2 + surcharge(a.0);
        let cb = b.2 + surcharge(b.0);
        let (mut plan, cost) = if cb < ca { (b, cb) } else { (a, ca) };
        plan.3 += surcharge(0);
        plan.2 = cost;
        plan
    }

    fn random_planner(rng: &mut Prng, nvme_factor: f64) -> Planner {
        let a = 10f64.powf(rng.next_f64() * 6.0 - 9.0); // 1e-9 .. 1e-3
        let c = 10f64.powf(rng.next_f64() * 6.0 - 9.0);
        let cost = CostModel {
            recompute_per_token_s: a,
            transfer_kv_per_token_s: c,
            transfer_act_per_token_s: c / 2.0,
            gpu_overhead_s: rng.next_f64() * 1e-4,
            link_latency_s: rng.next_f64() * 1e-4,
        };
        let policy = if rng.next_f64() < 0.5 {
            SchedulePolicy::RowByRow
        } else {
            SchedulePolicy::ColumnByColumn
        };
        let mut buckets = Vec::new();
        let step = 8 + rng.index(48);
        for i in 1..=(1 + rng.index(5)) {
            buckets.push(i * step);
        }
        let l_cap = if rng.next_f64() < 0.3 { 1 + rng.index(256) } else { usize::MAX };
        Planner::new(cost, policy, buckets, l_cap)
            .with_topology(four_tier_topology(nvme_factor))
    }

    #[test]
    fn property_plan_batch_reproduces_all_three_legacy_entry_points() {
        let cases = prop_cases(500);
        check_property("topology plan == legacy closed forms", cases, |rng| {
            let nvme_factor = 0.25 + rng.next_f64() * 8.0;
            let p = random_planner(rng, nvme_factor);
            let disk = p.topology().unwrap().tier_named("disk-nvme").unwrap();
            let n_lanes = 1 + rng.index(6);
            let lanes: Vec<usize> = (0..n_lanes).map(|_| 1 + rng.index(500)).collect();
            let shortest = *lanes.iter().min().unwrap();
            let resident = rng.index(shortest + 8);
            let l_floor = rng.index(shortest.saturating_sub(resident) + 8);
            let disk_prefix = rng.index(shortest.saturating_sub(resident + l_floor) + 8);

            // 2-tier: bare lanes (the legacy slice-based plan_batch)
            let got = p.plan_batch(&PlanInput::new(lanes.clone()));
            let want = oracle_tiered(&p, &lanes, 0, 0);
            if (got.l(), got.ideal_l) != (want.0, want.1)
                || got.predicted_s != want.2
                || got.baseline_s != want.3
            {
                return Err(format!("2-tier diverged: {got:?} vs {want:?} (lanes {lanes:?})"));
            }

            // 3-tier: resident suffix + dropped floor
            let got = p.plan_batch(
                &PlanInput::new(lanes.clone()).resident(resident).dropped_floor(l_floor),
            );
            let want = oracle_tiered(&p, &lanes, resident, l_floor);
            if (got.l(), got.ideal_l) != (want.0, want.1)
                || got.predicted_s != want.2
                || got.baseline_s != want.3
            {
                return Err(format!(
                    "3-tier diverged: {got:?} vs {want:?} \
                     (lanes {lanes:?}, r {resident}, floor {l_floor})"
                ));
            }

            // 4-tier: + the disk prefix span over the topology's NVMe rung
            let got = p.plan_batch(
                &PlanInput::new(lanes.clone())
                    .resident(resident)
                    .dropped_floor(l_floor)
                    .prefix(disk, disk_prefix),
            );
            let want = oracle_four_tier(&p, &lanes, resident, l_floor, disk_prefix, nvme_factor);
            if (got.l(), got.ideal_l) != (want.0, want.1)
                || got.predicted_s != want.2
                || got.baseline_s != want.3
            {
                return Err(format!(
                    "4-tier diverged: {got:?} vs {want:?} (lanes {lanes:?}, r {resident}, \
                     floor {l_floor}, disk {disk_prefix}, nvme {nvme_factor})"
                ));
            }
            Ok(())
        });
    }
}
