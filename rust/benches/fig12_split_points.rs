//! Paper Fig 12: optimal KV split point l* over the generation process.
//!
//! `cargo bench --bench fig12_split_points` — prints the paper-shaped rows and writes
//! `reports/fig12_split_points.txt` (see DESIGN.md §6 for the experiment index).

fn main() {
    std::fs::create_dir_all("reports").ok();
    kvpr::paper::fig12_splits().emit("fig12_split_points");
}
