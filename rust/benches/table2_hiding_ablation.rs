//! Paper Table 2: hiding KV recomputation under weight loading (ablation).
//!
//! `cargo bench --bench table2_hiding_ablation` — prints the paper-shaped rows and writes
//! `reports/table2_hiding_ablation.txt` (see DESIGN.md §6 for the experiment index).

fn main() {
    std::fs::create_dir_all("reports").ok();
    kvpr::paper::table2_hiding().emit("table2_hiding_ablation");
}
