//! Paper Tables 3-4: detailed latency-oriented results.
//!
//! `cargo bench --bench table34_detailed` — prints the paper-shaped rows and writes
//! `reports/table34_detailed.txt` (see DESIGN.md §6 for the experiment index).

fn main() {
    std::fs::create_dir_all("reports").ok();
    kvpr::paper::table34_detailed().emit("table34_detailed");
}
