//! Paper Fig 14: multi-GPU scaling vs FastDecode (shared-CPU bottleneck).
//!
//! `cargo bench --bench fig14_multigpu` — prints the paper-shaped rows and writes
//! `reports/fig14_multigpu.txt` (see DESIGN.md §6 for the experiment index).

fn main() {
    std::fs::create_dir_all("reports").ok();
    kvpr::paper::fig14_multigpu().emit("fig14_multigpu");
}
