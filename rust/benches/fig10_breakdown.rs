//! Paper Fig 10: runtime breakdown of an MHA block, KVPR vs FlexGen.
//!
//! `cargo bench --bench fig10_breakdown` — prints the paper-shaped rows and writes
//! `reports/fig10_breakdown.txt` (see DESIGN.md §6 for the experiment index).

fn main() {
    std::fs::create_dir_all("reports").ok();
    kvpr::paper::fig10_breakdown().emit("fig10_breakdown");
}
