//! Paper Fig 9: throughput with group-wise 4-bit KV quantization (OPT-13B).
//!
//! `cargo bench --bench fig9_compression` — prints the paper-shaped rows and writes
//! `reports/fig9_compression.txt` (see DESIGN.md §6 for the experiment index).

fn main() {
    std::fs::create_dir_all("reports").ok();
    kvpr::paper::fig9_compression().emit("fig9_compression");
}
