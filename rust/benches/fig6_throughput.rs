//! Paper Fig 6: decode throughput, KVPR vs FlexGen (seq sweep + batch sweep).
//!
//! `cargo bench --bench fig6_throughput` — prints the paper-shaped rows and writes
//! `reports/fig6_throughput.txt` (see DESIGN.md §6 for the experiment index).

fn main() {
    std::fs::create_dir_all("reports").ok();
    kvpr::paper::fig6_seq_sweep().emit("fig6_seq_sweep");
    kvpr::paper::fig6_batch_sweep().emit("fig6_batch_sweep");
}
