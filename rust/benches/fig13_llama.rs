//! Paper Fig 13: LLaMa2-7B/13B throughput vs baselines.
//!
//! `cargo bench --bench fig13_llama` — prints the paper-shaped rows and writes
//! `reports/fig13_llama.txt` (see DESIGN.md §6 for the experiment index).

fn main() {
    std::fs::create_dir_all("reports").ok();
    kvpr::paper::fig13_llama().emit("fig13_llama");
}
