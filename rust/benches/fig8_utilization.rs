//! Paper Fig 8: GPU utilization and memory during decode, KVPR vs FlexGen.
//!
//! `cargo bench --bench fig8_utilization` — prints the paper-shaped rows and writes
//! `reports/fig8_utilization.txt` (see DESIGN.md §6 for the experiment index).

fn main() {
    std::fs::create_dir_all("reports").ok();
    let (summary, timeline) = kvpr::paper::fig8_utilization();
    summary.emit("fig8_utilization");
    timeline.emit("fig8_timeline");
}
