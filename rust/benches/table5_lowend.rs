//! Paper Table 5: low-end system (RTX 5000, PCIe 4.0 x8).
//!
//! `cargo bench --bench table5_lowend` — prints the paper-shaped rows and writes
//! `reports/table5_lowend.txt` (see DESIGN.md §6 for the experiment index).

fn main() {
    std::fs::create_dir_all("reports").ok();
    kvpr::paper::table5_lowend().emit("table5_lowend");
}
