//! Paper Table 1: KV-cache size, PCIe latency vs KV computation latency.
//!
//! `cargo bench --bench table1_pcie_vs_compute` — prints the paper-shaped rows and writes
//! `reports/table1_pcie_vs_compute.txt` (see DESIGN.md §6 for the experiment index).

fn main() {
    std::fs::create_dir_all("reports").ok();
    let t = kvpr::paper::table1();
    t.emit("table1_pcie_vs_compute");
}
