//! Paper Fig 7: decode latency, single batch of 64, vs Accelerate/DeepSpeed.
//!
//! `cargo bench --bench fig7_latency` — prints the paper-shaped rows and writes
//! `reports/fig7_latency.txt` (see DESIGN.md §6 for the experiment index).

fn main() {
    std::fs::create_dir_all("reports").ok();
    kvpr::paper::fig7_latency().emit("fig7_latency");
}
