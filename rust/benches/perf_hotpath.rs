//! Hot-path microbenchmarks for the §Perf pass (EXPERIMENTS.md §Perf).
//!
//! Timed loops (no criterion in the vendored crate set) over the pieces
//! that sit on the decode request path:
//!   * LP solve (must be sub-µs — it runs per step per batch)
//!   * bucket quantisation
//!   * staging transpose (host rows → artifact layout)
//!   * int4 quant/dequant of a KV block
//!   * mini-JSON manifest parse (startup path)
//!   * simulator step throughput (bench harness speed itself)
//!   * pipelined serving loop: serial vs overlapped steps/s
//!   * sharded Router serving: aggregate throughput at 1/2/4 shards
//!   * prefix-sharing admission: admitted tokens/s, private vs shared

use std::time::{Duration, Instant};

use kvpr::config::{HardwareConfig, ModelConfig, WorkloadConfig};
use kvpr::coordinator::{
    ContinuousConfig, ContinuousServer, PipelineMode, PipelineTotals, Router, RouterConfig, Submit,
};
use kvpr::engine::{EngineConfig, EnginePolicy};
use kvpr::kvcache::quant;
use kvpr::kvstore::{
    simulate_eviction, EvictionSimConfig, EvictionSimReport, KvStore, KvStoreConfig, Lru,
    RecomputeAware,
};
use kvpr::obs::{EventKind, Phase, StepRecord, Tracer, TracerConfig};
use kvpr::scheduler::{
    CostModel, LinkSpec, PlanInput, Planner, SchedulePolicy, SplitSolver, TierTopology,
};
use kvpr::sim::{simulate_decode, Policy, RunConfig};
use kvpr::transfer::LinkConfig;
use kvpr::util::stats::Summary;
use kvpr::util::table::Table;
use kvpr::workload::{Arrival, LenDist, SloTargets, TrafficClass, WorkloadSpec};

fn time_per_iter<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    // warmup
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    std::fs::create_dir_all("reports").ok();
    let mut t = Table::new(
        "perf_hotpath — request-path microbenchmarks",
        &["op", "iters", "time/iter", "notes"],
    );

    // LP solve
    let cost = CostModel::from_hardware(&HardwareConfig::a100_x16(), &ModelConfig::opt_6_7b(), 32);
    let solver = SplitSolver::new(cost.clone(), SchedulePolicy::RowByRow);
    let dt = time_per_iter(1_000_000, || {
        std::hint::black_box(solver.solve(std::hint::black_box(1024), 1024));
    });
    t.row(&[
        "LP solve (closed form)".into(),
        "1M".into(),
        kvpr::util::fmt_secs(dt),
        "per decode step".into(),
    ]);

    // exhaustive oracle for comparison
    let dt = time_per_iter(2_000, || {
        std::hint::black_box(solver.solve_exhaustive(std::hint::black_box(1024), 1024));
    });
    t.row(&[
        "LP solve (exhaustive)".into(),
        "2k".into(),
        kvpr::util::fmt_secs(dt),
        "oracle, not on hot path".into(),
    ]);

    // bucket quantisation
    let buckets = [32usize, 64, 96];
    let dt = time_per_iter(1_000_000, || {
        std::hint::black_box(solver.quantize_to_buckets(std::hint::black_box(120), &buckets, 120));
    });
    t.row(&[
        "bucket quantisation".into(),
        "1M".into(),
        kvpr::util::fmt_secs(dt),
        "per decode step/layer".into(),
    ]);

    // staging transpose: tiny-model-shaped (b=4, 100 rows, h=256, cap 128)
    let rows = vec![0.5f32; 100 * 4 * 256];
    let mut out = Vec::with_capacity(4 * 128 * 256);
    let dt = time_per_iter(5_000, || {
        kvpr::engine_stage_padded_bench(&rows, 100, 4, 256, 128, &mut out);
        std::hint::black_box(&out);
    });
    t.row(&[
        "staging transpose".into(),
        "5k".into(),
        kvpr::util::fmt_secs(dt),
        "per layer per step (b=4)".into(),
    ]);

    // int4 quant + dequant of one layer's transferred KV (tiny model)
    let data = vec![0.25f32; 2 * 100 * 4 * 256];
    let mut deq = Vec::new();
    let dt = time_per_iter(500, || {
        let b = quant::quantize(&data, quant::DEFAULT_GROUP).unwrap();
        quant::dequantize(&b, &mut deq);
        std::hint::black_box(&deq);
    });
    t.row(&[
        "int4 quant+dequant".into(),
        "500".into(),
        kvpr::util::fmt_secs(dt),
        format!("{} elems", data.len()),
    ]);

    // manifest JSON parse (startup)
    if let Ok(text) = std::fs::read_to_string("artifacts/manifest.json") {
        let dt = time_per_iter(2_000, || {
            std::hint::black_box(kvpr::util::json::Json::parse(&text).unwrap());
        });
        t.row(&[
            "manifest parse".into(),
            "2k".into(),
            kvpr::util::fmt_secs(dt),
            format!("{} bytes", text.len()),
        ]);
    }

    // simulator throughput (bench harness speed)
    let cfg = RunConfig::new(
        ModelConfig::opt_6_7b(),
        HardwareConfig::a100_x16(),
        WorkloadConfig::throughput_oriented(512, 8),
        Policy::Kvpr,
    );
    let mut tasks = 0usize;
    let dt = time_per_iter(20, || {
        let r = simulate_decode(&cfg);
        tasks = r.n_tasks;
        std::hint::black_box(r);
    });
    t.row(&[
        "sim decode (opt-6.7b, 8 steps, 32x8)".into(),
        "20".into(),
        kvpr::util::fmt_secs(dt),
        format!("{tasks} tasks"),
    ]);

    // kvstore eviction-policy comparison (skewed reuse, tight budget):
    // LRU vs recompute-aware, analytically — the numbers that start the
    // kvstore bench trajectory (BENCH_kvstore.json)
    let cost = CostModel::from_hardware(&HardwareConfig::a100_x16(), &ModelConfig::opt_6_7b(), 32);
    let ecfg = EvictionSimConfig::skewed_reuse(cost.clone());
    let lru = simulate_eviction(&ecfg, &Lru);
    let ra = simulate_eviction(&ecfg, &RecomputeAware::new(cost.clone()));
    let dt = time_per_iter(50, || {
        std::hint::black_box(simulate_eviction(&ecfg, &Lru));
    });
    t.row(&[
        "kvstore eviction sim (8 seqs)".into(),
        "50".into(),
        kvpr::util::fmt_secs(dt),
        format!(
            "ra {:.0} vs lru {:.0} steps/s",
            ra.steps_per_s, lru.steps_per_s
        ),
    ]);

    // the same comparison with a contended gpu tier: async demotions ride
    // the policy, so the trajectory also tracks writeback traffic
    let tcfg = EvictionSimConfig::skewed_reuse_tiered(cost.clone());
    let tlru = simulate_eviction(&tcfg, &Lru);
    let tra = simulate_eviction(&tcfg, &RecomputeAware::new(cost.clone()));
    t.row(&[
        "kvstore tiered sim (async demotions)".into(),
        "1".into(),
        kvpr::util::fmt_secs(0.0),
        format!("{} demotions, {:.1} ms writeback", tlru.demotions, tlru.demote_link_s * 1e3),
    ]);

    // four-tier: an NVMe disk tier absorbs admission shortfalls as spills
    // instead of KV drops; the trajectory tracks spill traffic and the
    // read-through surcharge the spill-victim choice controls
    let fcfg = EvictionSimConfig::skewed_reuse_four_tier(cost.clone());
    let flru = simulate_eviction(&fcfg, &Lru);
    let fra = simulate_eviction(&fcfg, &RecomputeAware::new(cost));
    t.row(&[
        "kvstore four-tier sim (disk spill)".into(),
        "1".into(),
        kvpr::util::fmt_secs(0.0),
        format!(
            "{} spills, {:.1} ms nvme writeback, {:.2} ms read-through",
            flru.spills,
            flru.spill_link_s * 1e3,
            flru.readthrough_s * 1e3
        ),
    ]);

    // topology-driven planning: the one plan_batch fold the continuous
    // loop runs per group per step.  One planner per chain length — a
    // genuine 2/3/4-tier sweep, each over its own declared chain with a
    // matching PlanInput shape — so plan latency (which must stay sub-µs:
    // it multiplies by groups × steps) is tracked as a function of chain
    // depth, and the slack prediction (the adaptive migration budget)
    // alongside it.
    let pcost =
        CostModel::from_hardware(&HardwareConfig::a100_x16(), &ModelConfig::opt_6_7b(), 1);
    let pcie = LinkSpec { bytes_per_sec: 28e9, latency_s: 30e-6 }; // PCIe 4.0 x16-ish
    let mut topo_json = Vec::new();
    for (name, tiers) in [("two_tier", 2usize), ("three_tier", 3), ("four_tier", 4)] {
        let topo = match tiers {
            2 => TierTopology::device_host(2 << 30, pcie),
            3 => TierTopology::standard(2 << 30, 16u64 << 30, 64u64 << 30).calibrated(&pcie),
            _ => TierTopology::standard(2 << 30, 16u64 << 30, 64u64 << 30)
                .with_disk(1u64 << 40, 0.9) // datacenter NVMe below dram
                .calibrated(&pcie),
        };
        let disk = topo.tier_named("disk-nvme");
        let planner = Planner::new(
            pcost.clone(),
            SchedulePolicy::RowByRow,
            vec![128, 256, 384, 512],
            usize::MAX,
        )
        .with_topology(topo);
        let mut input = PlanInput::new(vec![1024; 32]);
        if tiers >= 3 {
            input = input.resident(256).dropped_floor(128);
        }
        if tiers >= 4 {
            input = input.prefix(disk.expect("four-tier chain has a disk rung"), 256);
        }
        let plan = planner.plan_batch(&input);
        let dt = time_per_iter(200_000, || {
            std::hint::black_box(planner.plan_batch(std::hint::black_box(&input)));
        });
        t.row(&[
            format!("topology plan ({name})"),
            "200k".into(),
            kvpr::util::fmt_secs(dt),
            format!("l={}, slack {} B", plan.l(), plan.link_slack_bytes),
        ]);
        topo_json.push(format!(
            "\"{name}\": {{ \"plans_per_s\": {:.3}, \"slack_bytes\": {}, \"l\": {} }}",
            1.0 / dt,
            plan.link_slack_bytes,
            plan.l()
        ));
    }

    // observability overhead: a synthetic serving step — eight four-tier
    // plan_batch folds plus the per-step tracer traffic the continuous
    // loop emits (phase spans, per-group plan events, a step record) —
    // timed against the no-op sink and against a live ring-buffer tracer.
    // BENCH_baseline.json's ratio_gates pins enabled ≥ 95 % of disabled.
    let obs_topo = TierTopology::standard(2 << 30, 16u64 << 30, 64u64 << 30)
        .with_disk(1u64 << 40, 0.9)
        .calibrated(&pcie);
    let obs_disk = obs_topo.tier_named("disk-nvme").expect("four-tier chain has a disk rung");
    let obs_planner = Planner::new(
        pcost.clone(),
        SchedulePolicy::RowByRow,
        vec![128, 256, 384, 512],
        usize::MAX,
    )
    .with_topology(obs_topo);
    let obs_input = PlanInput::new(vec![1024; 128])
        .resident(256)
        .dropped_floor(128)
        .prefix(obs_disk, 256);
    let synthetic_step = |tracer: &Tracer, step: u64| {
        tracer.set_step(step);
        tracer.emit(|| EventKind::PhaseBegin { phase: Phase::Step });
        tracer.emit(|| EventKind::PhaseBegin { phase: Phase::Plan });
        let mut predicted = 0.0;
        let mut slack = 0u64;
        for g in 0..8 {
            let pl = obs_planner.plan_batch(&obs_input);
            predicted += pl.predicted_s;
            slack = pl.link_slack_bytes;
            tracer.emit(|| EventKind::Plan {
                group: g,
                l: pl.l(),
                predicted_s: pl.predicted_s,
                slack_bytes: pl.link_slack_bytes,
            });
            std::hint::black_box(&pl);
        }
        tracer.emit(|| EventKind::PhaseEnd { phase: Phase::Plan });
        tracer.emit(|| EventKind::PhaseEnd { phase: Phase::Step });
        tracer.record_step(StepRecord {
            step,
            predicted_s: predicted,
            slack_bytes: slack,
            granted_bytes: slack,
            measured_s: predicted,
            launched: 0,
            launched_wire_bytes: 0,
            landed: 0,
        });
    };
    let off = Tracer::disabled();
    let mut step_no = 0u64;
    let dt_off = time_per_iter(2_000, || {
        synthetic_step(&off, step_no);
        step_no += 1;
    });
    // ring-only retention: the steady-state production configuration
    let on = Tracer::new(TracerConfig { retain_all: false, ..TracerConfig::default() });
    let mut step_no = 0u64;
    let dt_on = time_per_iter(2_000, || {
        synthetic_step(&on, step_no);
        step_no += 1;
    });
    t.row(&[
        "obs synthetic step (8 plans + spans)".into(),
        "2k".into(),
        kvpr::util::fmt_secs(dt_on),
        format!("enabled/disabled throughput {:.3}", dt_off / dt_on),
    ]);

    // pipelined step runtime: the identical bursty trace served end-to-end
    // through the continuous loop in both pipeline modes.  Overlapped mode
    // pre-solves the next step's plans, double-buffers group staging and
    // pumps migrations inside the compute shadow, so its throughput must
    // never fall below the serial loop's — BENCH_baseline.json's
    // ratio_gates pins pipeline.overlapped ≥ 100 % of pipeline.serial
    // (best-of-3 interleaved trials keep the claim machine-independent).
    let pipe_spec = WorkloadSpec {
        name: "pipeline_bench".into(),
        seed: 7,
        requests: 8,
        arrivals: Arrival::Bursty { burst: 4, gap: 2 },
        classes: vec![TrafficClass {
            name: "chat".into(),
            weight: 1.0,
            prompt: LenDist::Fixed { steps: 16 },
            gen: LenDist::Fixed { steps: 32 },
            think: LenDist::Fixed { steps: 0 },
            shared_prefix: 0,
        }],
        slo: SloTargets { ttft_s: 30.0, tpot_s: 30.0 },
    };
    let pipe_trace = pipe_spec.generate();
    let serve = |mode: PipelineMode| -> (f64, PipelineTotals) {
        let mut e = EngineConfig::new(EnginePolicy::Kvpr);
        e.weights_offloaded = true;
        e.link = LinkConfig::with_bandwidth(100e6);
        e.seed = 42;
        let mut c = ContinuousConfig::new("artifacts", e);
        c.max_group = 2;
        c.max_groups = 4;
        c.prompt_bucket = 16;
        c.admit_wait = Duration::from_millis(1);
        c.kv_budget_bytes = 64 << 20;
        c.pipeline = mode;
        let server = ContinuousServer::start(c).expect("start continuous server");
        let t0 = Instant::now();
        for h in server.dispatch(&pipe_trace) {
            h.wait().expect("request served");
        }
        let dt = t0.elapsed().as_secs_f64();
        let steps = server.metrics().tokens() as f64;
        let totals = server.metrics().pipeline_totals();
        server.shutdown().expect("server shutdown");
        (steps / dt, totals)
    };
    let mut serial_sps = 0.0f64;
    let mut over_sps = 0.0f64;
    let mut over_totals = PipelineTotals::default();
    for _ in 0..3 {
        serial_sps = serial_sps.max(serve(PipelineMode::Serial).0);
        let (sps, totals) = serve(PipelineMode::Overlapped);
        if sps > over_sps {
            over_sps = sps;
            over_totals = totals;
        }
    }
    t.row(&[
        "pipeline serve (8 reqs × 32 steps)".into(),
        "3×2".into(),
        kvpr::util::fmt_secs(1.0 / over_sps),
        format!(
            "overlapped/serial {:.3}, {} adopted / {} fallback",
            over_sps / serial_sps,
            over_totals.plans_adopted,
            over_totals.fallback_resolves
        ),
    ]);

    // sharded serving: the identical bursty trace through the Router
    // front-end at 1/2/4 worker shards.  Each shard owns a private gpu
    // tier and its own engine thread over shared host tiers, so extra
    // shards add decode lanes; placement is suffix-affine with
    // load-spread for fresh sessions.  BENCH_baseline.json's ratio_gates
    // pins sharding.two_shard ≥ 100 % of sharding.one_shard (best-of-3
    // interleaved trials keep the claim machine-independent).
    let serve_sharded = |shards: usize| -> f64 {
        let mut e = EngineConfig::new(EnginePolicy::Kvpr);
        e.weights_offloaded = true;
        e.link = LinkConfig::with_bandwidth(100e6);
        e.seed = 42;
        let base = ContinuousConfig::builder("artifacts", e)
            .max_group(2)
            .max_groups(4)
            .prompt_bucket(16)
            .admit_wait(Duration::from_millis(1))
            .kv_budget_bytes(64 << 20)
            .build();
        let router = Router::start(RouterConfig::new(shards, base)).expect("start router");
        let t0 = Instant::now();
        for h in router.dispatch(&pipe_trace) {
            h.wait().expect("request served");
        }
        let dt = t0.elapsed().as_secs_f64();
        let tokens = router.total_tokens() as f64;
        router.shutdown().expect("router shutdown");
        tokens / dt
    };
    let mut shard_sps = [0.0f64; 3];
    for _ in 0..3 {
        for (slot, n) in [1usize, 2, 4].into_iter().enumerate() {
            shard_sps[slot] = shard_sps[slot].max(serve_sharded(n));
        }
    }
    t.row(&[
        "sharded serve (1/2/4 shards)".into(),
        "3×3".into(),
        kvpr::util::fmt_secs(1.0 / shard_sps[1]),
        format!(
            "two/one {:.3}, four/one {:.3}",
            shard_sps[1] / shard_sps[0],
            shard_sps[2] / shard_sps[0]
        ),
    ]);

    // trace-driven workload mixes: each named generator lowered to a
    // trace and replayed through the analytic sim (the serving loop's
    // twin) — per-mix decode throughput plus the queueing-delay
    // component of TTFT in steps (p99 of admission round − arrival round)
    let wcost = CostModel::from_hardware(&HardwareConfig::a100_x16(), &ModelConfig::opt_6_7b(), 32);
    let mut wl_json = Vec::new();
    for name in WorkloadSpec::mix_names() {
        let spec = WorkloadSpec::named(name).expect("named mix");
        let trace = spec.generate();
        let wcfg = EvictionSimConfig::from_trace(wcost.clone(), &trace);
        let rep = simulate_eviction(&wcfg, &RecomputeAware::new(wcost.clone()));
        let dt = time_per_iter(50, || {
            std::hint::black_box(simulate_eviction(&wcfg, &RecomputeAware::new(wcost.clone())));
        });
        let mut delays = Summary::new();
        for &d in &rep.admit_delay_steps {
            delays.add(d as f64);
        }
        let ttft_p99_steps = if delays.count() == 0 { 0.0 } else { delays.p99() };
        t.row(&[
            format!("workload replay ({name})"),
            "50".into(),
            kvpr::util::fmt_secs(dt),
            format!(
                "{} reqs, {:.0} steps/s, p99 TTFT {:.0} steps",
                trace.requests.len(),
                rep.steps_per_s,
                ttft_p99_steps
            ),
        ]);
        wl_json.push(format!(
            "\"{name}\": {{ \"steps_per_s\": {:.3}, \"ttft_p99_steps\": {:.1}, \"requests\": {}, \"completed\": {} }}",
            rep.steps_per_s,
            ttft_p99_steps,
            trace.requests.len(),
            rep.completed
        ));
    }

    // cross-request prefix sharing: admission throughput at one fixed dram
    // budget, private vs shared.  Every request wants 5 blocks over the
    // same 4-block preamble; with the registry on, later requests adopt
    // the registered head blocks in place (zero new bytes), so the same
    // budget admits far more prompt tokens per second even though each
    // shared admission also pays the content hash.  BENCH_baseline.json's
    // ratio_gates pins prefix_share.shared ≥ 100 % of
    // prefix_share.unshared (admitted tokens/s, same machine).
    const SHARE_BT: usize = 16; // block tokens
    const SHARE_BB: u64 = 4096; // block bytes
    let share_store = |sharing: bool| -> KvStore {
        let link = LinkConfig::with_bandwidth(500e6);
        let mut s = KvStore::new(
            KvStoreConfig {
                gpu_bytes: 0,
                pinned_bytes: 0,
                dram_bytes: 64 * SHARE_BB,
                disk_bytes: 0,
                block_tokens: SHARE_BT,
                nvme_link: LinkConfig::nvme_below(&link),
                link,
                wire_elem_bytes: 4.0,
                promote_cooldown: 0,
                spill_cooldown: 0,
                spill_floor: 0.0,
                spill_watermark: 0.0,
                spill_max_per_step: 2,
                shared_host: None,
            },
            Box::new(Lru),
        );
        if sharing {
            s.enable_prefix_sharing();
        }
        s
    };
    let preamble: Vec<u8> =
        b"sys: shared retrieval preamble ".iter().copied().cycle().take(4 * SHARE_BT).collect();
    let admit_pass = |sharing: bool| -> (f64, usize) {
        let mut admitted_tokens = 0usize;
        let dt = time_per_iter(1_000, || {
            let mut s = share_store(sharing);
            admitted_tokens = 0;
            for seq in 0..32u64 {
                let ok = if sharing {
                    s.admit_shared(seq, 5 * SHARE_BB, 5, &preamble).is_ok()
                } else {
                    s.admit(seq, 5 * SHARE_BB, 5).is_ok()
                };
                if ok {
                    admitted_tokens += 5 * SHARE_BT;
                }
            }
            std::hint::black_box(&s);
        });
        (admitted_tokens as f64 / dt, admitted_tokens)
    };
    let (unshared_tps, unshared_tokens) = admit_pass(false);
    let (shared_tps, shared_tokens) = admit_pass(true);
    t.row(&[
        "prefix-share admission (32 reqs)".into(),
        "1k".into(),
        kvpr::util::fmt_secs(1.0 / shared_tps * shared_tokens as f64),
        format!(
            "shared/unshared {:.3}, {} vs {} tokens admitted",
            shared_tps / unshared_tps,
            shared_tokens,
            unshared_tokens
        ),
    ]);

    let json = format!(
        "{{\n  \"bench\": \"kvstore\",\n  \"policies\": {{\n    \"lru\": {},\n    \"recompute_aware\": {}\n  }},\n  \"tiered\": {{\n    \"lru\": {},\n    \"recompute_aware\": {}\n  }},\n  \"four_tier\": {{\n    \"lru\": {},\n    \"recompute_aware\": {}\n  }},\n  \"topology_plan\": {{\n    {},\n    {},\n    {}\n  }},\n  \"obs_overhead\": {{\n    \"disabled\": {{ \"steps_per_s\": {:.3} }},\n    \"enabled\": {{ \"steps_per_s\": {:.3} }}\n  }},\n  \"pipeline\": {{\n    \"serial\": {{ \"steps_per_s\": {:.3} }},\n    \"overlapped\": {{ \"steps_per_s\": {:.3}, \"prestaged_steps\": {}, \"plans_adopted\": {}, \"fallback_resolves\": {} }}\n  }},\n  \"sharding\": {{\n    \"one_shard\": {{ \"steps_per_s\": {:.3} }},\n    \"two_shard\": {{ \"steps_per_s\": {:.3} }},\n    \"four_shard\": {{ \"steps_per_s\": {:.3} }}\n  }},\n  \"workload\": {{\n    {}\n  }},\n  \"prefix_share\": {{\n    \"unshared\": {{ \"admitted_tokens_per_s\": {:.3}, \"admitted_tokens\": {} }},\n    \"shared\": {{ \"admitted_tokens_per_s\": {:.3}, \"admitted_tokens\": {} }}\n  }}\n}}\n",
        policy_json(&lru),
        policy_json(&ra),
        policy_json(&tlru),
        policy_json(&tra),
        policy_json(&flru),
        policy_json(&fra),
        topo_json[0],
        topo_json[1],
        topo_json[2],
        1.0 / dt_off,
        1.0 / dt_on,
        serial_sps,
        over_sps,
        over_totals.prestaged_steps,
        over_totals.plans_adopted,
        over_totals.fallback_resolves,
        shard_sps[0],
        shard_sps[1],
        shard_sps[2],
        wl_json.join(",\n    "),
        unshared_tps,
        unshared_tokens,
        shared_tps,
        shared_tokens
    );
    if let Err(e) = std::fs::write("BENCH_kvstore.json", &json) {
        eprintln!("BENCH_kvstore.json not written: {e}");
    } else {
        println!("wrote BENCH_kvstore.json");
    }

    t.emit("perf_hotpath");
}

fn policy_json(r: &EvictionSimReport) -> String {
    format!(
        "{{ \"steps_per_s\": {:.3}, \"link_busy_frac\": {:.4}, \"evictions\": {}, \"demotions\": {}, \"demote_link_s\": {:.6}, \"spills\": {}, \"spill_link_s\": {:.6}, \"readthrough_s\": {:.6}, \"steps\": {}, \"peak_concurrency\": {} }}",
        r.steps_per_s,
        r.link_busy_frac,
        r.evictions,
        r.demotions,
        r.demote_link_s,
        r.spills,
        r.spill_link_s,
        r.readthrough_s,
        r.steps,
        r.peak_concurrency
    )
}
