//! Rust↔JAX parity: the PJRT artifacts must compute exactly what the
//! pure-Rust reference model computes, and the three decode paths (full /
//! fused-partial / split recompute+merge) must agree with each other.
//!
//! These tests require `make artifacts`; they are skipped (pass trivially)
//! when the artifacts are absent so `cargo test` stays green pre-build.

use std::path::PathBuf;

use kvpr::model::{ModelWeights, RefModel};
use kvpr::runtime::{ArgValue, Runtime};

fn runtime() -> Option<Runtime> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json")
        .exists()
        .then(|| Runtime::load(&dir).expect("runtime loads"))
}

fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol + tol * x.abs().max(y.abs()),
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

/// Build weight args for one layer in canonical order.
fn layer_args<'a>(w: &'a ModelWeights, layer: usize) -> Vec<ArgValue<'a>> {
    w.layer(layer)
        .iter()
        .map(|(_, d, _)| ArgValue::F32(d.as_slice()))
        .collect()
}

#[test]
fn prefill_artifact_matches_reference() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().clone();
    let w = ModelWeights::generate(&m.model, 11);
    let rm = RefModel::new(w.clone());
    let (b, sp) = (1, 16);
    let ids: Vec<i32> = (0..sp as i32).map(|i| (i * 13 + 7) % 512).collect();

    let art = rt.artifact(&m.prefill_name(b, sp)).unwrap();
    let mut args: Vec<ArgValue> = vec![
        ArgValue::I32Slice(&ids),
        ArgValue::F32(&w.tok_table),
        ArgValue::F32(&w.pos_table),
        ArgValue::F32(&w.lnf_g),
        ArgValue::F32(&w.lnf_b),
    ];
    for i in 0..m.model.n_layers {
        args.extend(layer_args(&w, i));
    }
    let out = art.call(&args).unwrap();

    let (logits_ref, per_layer) = rm.prefill(&ids, b, sp);
    close(&out[0], &logits_ref, 2e-3, "prefill logits");
    // per-layer K and X stacks
    let chunk = b * sp * m.model.hidden;
    for i in 0..m.model.n_layers {
        let (k_ref, _v_ref, x_ref) = &per_layer[i];
        close(&out[1][i * chunk..(i + 1) * chunk], k_ref, 2e-3, "K stack");
        close(&out[3][i * chunk..(i + 1) * chunk], x_ref, 2e-3, "X stack");
    }
    // greedy decisions must agree
    assert_eq!(
        RefModel::argmax(&out[0], m.model.vocab),
        RefModel::argmax(&logits_ref, m.model.vocab)
    );
}

#[test]
fn decode_full_artifact_matches_reference() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().clone();
    let h = m.model.hidden;
    let cap = m.seq_cap;
    let w = ModelWeights::generate(&m.model, 12);
    let rm = RefModel::new(w.clone());
    let b = 1;
    let kv_len = 40;

    let mut rng = kvpr::util::prng::Prng::new(5);
    let x: Vec<f32> = rng.normal_vec_f32(b * h, 0.1);
    let kc: Vec<f32> = rng.normal_vec_f32(b * cap * h, 0.1);
    let vc: Vec<f32> = rng.normal_vec_f32(b * cap * h, 0.1);

    let art = rt.artifact(&m.decode_full_name(b)).unwrap();
    let mut args: Vec<ArgValue> = vec![
        ArgValue::F32(&x),
        ArgValue::F32(&kc),
        ArgValue::F32(&vc),
        ArgValue::I32(kv_len as i32),
    ];
    args.extend(layer_args(&w, 0));
    let out = art.call(&args).unwrap();

    let (y_ref, k_ref, v_ref) = rm.decode_layer_full(0, &x, &kc, &vc, cap, kv_len, b);
    close(&out[0], &y_ref, 2e-3, "decode y");
    close(&out[1], &k_ref, 2e-3, "decode k_new");
    close(&out[2], &v_ref, 2e-3, "decode v_new");
}

#[test]
fn split_path_equals_fused_equals_full() {
    // The three decode paths must agree on a *consistent* state: the
    // cache prefix really is the projection of the activation prefix.
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().clone();
    let h = m.model.hidden;
    let cap = m.seq_cap;
    let w = ModelWeights::generate(&m.model, 13);
    let (b, l, kv_len) = (1usize, 32usize, 50usize);

    let mut rng = kvpr::util::prng::Prng::new(9);
    let x: Vec<f32> = rng.normal_vec_f32(b * h, 0.1);
    let x_pre: Vec<f32> = rng.normal_vec_f32(b * l * h, 0.1);
    let k_rest: Vec<f32> = rng.normal_vec_f32(b * (cap - l) * h, 0.1);
    let v_rest: Vec<f32> = rng.normal_vec_f32(b * (cap - l) * h, 0.1);

    // recompute K/V[0:l] via the recompute artifact (ground truth for the
    // consistent full cache)
    let lw = w.layer(0);
    let rec = rt.artifact(&m.recompute_name(b, l)).unwrap();
    let re = rec
        .call(&[
            ArgValue::F32(&x_pre),
            ArgValue::F32(lw.get("ln1_g")),
            ArgValue::F32(lw.get("ln1_b")),
            ArgValue::F32(lw.get("wk")),
            ArgValue::F32(lw.get("bk")),
            ArgValue::F32(lw.get("wv")),
            ArgValue::F32(lw.get("bv")),
        ])
        .unwrap();

    // full path over the merged cache
    let mut kc = re[0].clone();
    kc.extend_from_slice(&k_rest);
    let mut vc = re[1].clone();
    vc.extend_from_slice(&v_rest);
    let full = rt.artifact(&m.decode_full_name(b)).unwrap();
    let mut args: Vec<ArgValue> = vec![
        ArgValue::F32(&x),
        ArgValue::F32(&kc),
        ArgValue::F32(&vc),
        ArgValue::I32(kv_len as i32),
    ];
    args.extend(layer_args(&w, 0));
    let out_full = full.call(&args).unwrap();

    // fused partial path
    let fused = rt.artifact(&m.decode_partial_name(b, l)).unwrap();
    let mut args: Vec<ArgValue> = vec![
        ArgValue::F32(&x),
        ArgValue::F32(&x_pre),
        ArgValue::F32(&k_rest),
        ArgValue::F32(&v_rest),
        ArgValue::I32(kv_len as i32),
    ];
    args.extend(layer_args(&w, 0));
    let out_fused = fused.call(&args).unwrap();

    // split path: recompute (done above) + merge
    let merge = rt.artifact(&m.decode_merge_name(b, l)).unwrap();
    let mut args: Vec<ArgValue> = vec![
        ArgValue::F32(&x),
        ArgValue::F32(&re[0]),
        ArgValue::F32(&re[1]),
        ArgValue::F32(&k_rest),
        ArgValue::F32(&v_rest),
        ArgValue::I32(kv_len as i32),
    ];
    args.extend(layer_args(&w, 0));
    let out_split = merge.call(&args).unwrap();

    for i in 0..3 {
        close(&out_full[i], &out_fused[i], 1e-4, "full vs fused");
        close(&out_full[i], &out_split[i], 1e-4, "full vs split");
    }
}

#[test]
fn lm_head_and_embed_match_reference() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().clone();
    let w = ModelWeights::generate(&m.model, 14);
    let rm = RefModel::new(w.clone());
    let b = 4;

    let ids: Vec<i32> = vec![1, 100, 255, 300];
    let embed = rt.artifact(&m.embed_decode_name(b)).unwrap();
    let x = embed
        .call(&[
            ArgValue::I32Slice(&ids),
            ArgValue::I32(17),
            ArgValue::F32(&w.tok_table),
            ArgValue::F32(&w.pos_table),
        ])
        .unwrap();
    close(&x[0], &rm.embed_decode(&ids, 17), 1e-4, "embed");

    let head = rt.artifact(&m.lm_head_name(b)).unwrap();
    let logits = head
        .call(&[
            ArgValue::F32(&x[0]),
            ArgValue::F32(&w.tok_table),
            ArgValue::F32(&w.lnf_g),
            ArgValue::F32(&w.lnf_b),
        ])
        .unwrap();
    close(&logits[0], &rm.lm_head(&x[0]), 2e-3, "lm_head");
}
