//! Observability e2e: replay a seeded workload trace through the
//! continuous server with tracing enabled and hold the trace to account:
//!
//! * every admitted request shows the complete
//!   arrive → admit → first-token → retire lifecycle, in order;
//! * per-step launched wire bytes never exceed the recorded grant except
//!   through the migration engine's single oversized-launch progress
//!   override;
//! * plan-vs-actual residuals are finite and the summary exports;
//! * tracing changes nothing: tokens are bit-identical to an untraced
//!   run (interpreter runtime);
//! * the Chrome `trace_event` export is parseable and byte-identical
//!   across two replays on the deterministic step clock.
//!
//! Like `coordinator_e2e.rs` these need **no artifacts** (interpreter
//! fallback).

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;

use kvpr::coordinator::{ContinuousConfig, ContinuousServer, Submit, TieredKvConfig};
use kvpr::engine::{EngineConfig, EnginePolicy};
use kvpr::obs::{chrome_trace, Event, EventKind, MigPhase, Phase, Tracer, TracerConfig};
use kvpr::scheduler::TierTopology;
use kvpr::transfer::LinkConfig;
use kvpr::util::clock::ClockMode;
use kvpr::util::json::Json;
use kvpr::workload::{Arrival, LenDist, Trace, TrafficClass, WorkloadSpec};

/// Serialise the heavy tests: each spins up engine + link worker threads.
static HEAVY: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    HEAVY.lock().unwrap_or_else(|p| p.into_inner())
}

fn engine_cfg() -> EngineConfig {
    let mut e = EngineConfig::new(EnginePolicy::Kvpr);
    e.weights_offloaded = true;
    e.link = LinkConfig::with_bandwidth(100e6);
    e.seed = 42;
    e
}

fn continuous_cfg(max_group: usize, max_groups: usize) -> ContinuousConfig {
    let mut c = ContinuousConfig::new("artifacts", engine_cfg());
    c.max_group = max_group;
    c.max_groups = max_groups;
    c.prompt_bucket = 16;
    c.admit_wait = Duration::from_millis(1);
    c
}

/// Six requests in three bursts of two (arrival steps 0,0,3,3,6,6).
fn spec(gen: LenDist) -> WorkloadSpec {
    WorkloadSpec {
        name: "obs_e2e".into(),
        seed: 17,
        requests: 6,
        arrivals: Arrival::Bursty { burst: 2, gap: 3 },
        classes: vec![TrafficClass {
            name: "chat".into(),
            weight: 1.0,
            prompt: LenDist::Fixed { steps: 16 },
            gen,
            think: LenDist::Fixed { steps: 0 },
            shared_prefix: 0,
        }],
        slo: kvpr::workload::SloTargets { ttft_s: 30.0, tpot_s: 30.0 },
    }
}

/// Tiered serving config exercising real migrations under a tight host
/// tier (mirrors `workload_trace.rs`'s host-pressure scenario).
fn tiered_cfg() -> ContinuousConfig {
    let mut cfg = continuous_cfg(1, 6);
    cfg.kv_budget_bytes = 200 << 10;
    cfg.tiering = Some(TieredKvConfig {
        topology: TierTopology::standard(0, 64 << 10, 2 << 20).with_disk(64 << 20, 0.5),
        block_tokens: 16,
        prefetch_blocks: 1,
        max_inflight: 8,
        promote_cooldown: 2,
        step_budget_override: Some(4 << 20),
        ..TieredKvConfig::default()
    });
    cfg
}

fn run(cfg: ContinuousConfig, trace: &Trace) -> (Vec<Vec<i32>>, Tracer) {
    let server = ContinuousServer::start(cfg).unwrap();
    let handles = server.dispatch(trace);
    let mut tokens = Vec::with_capacity(trace.requests.len());
    for (h, r) in handles.into_iter().zip(&trace.requests) {
        let resp = h.wait().unwrap();
        assert_eq!(resp.tokens.len(), r.gen_tokens, "request {} length", r.id);
        tokens.push(resp.tokens);
    }
    let tracer = server.tracer();
    server.shutdown().unwrap();
    (tokens, tracer)
}

fn interpreted() -> bool {
    !std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json"))
        .exists()
}

/// Per-request lifecycle milestones, in event order (sequence numbers).
#[derive(Default)]
struct Lifecycle {
    arrive: Option<u64>,
    admit: Option<u64>,
    first_token: Option<u64>,
    retire: Option<u64>,
}

fn lifecycles(events: &[Event]) -> HashMap<u64, Lifecycle> {
    let mut map: HashMap<u64, Lifecycle> = HashMap::new();
    for ev in events {
        match ev.kind {
            EventKind::ReqArrive { id } => map.entry(id).or_default().arrive = Some(ev.seq),
            EventKind::ReqAdmit { id, .. } => map.entry(id).or_default().admit = Some(ev.seq),
            EventKind::ReqFirstToken { id } => {
                map.entry(id).or_default().first_token = Some(ev.seq)
            }
            EventKind::ReqRetire { id, .. } => map.entry(id).or_default().retire = Some(ev.seq),
            _ => {}
        }
    }
    map
}

#[test]
fn traced_tiered_replay_audits_lifecycles_grants_and_residuals() {
    let _g = lock();
    let spec = spec(LenDist::Fixed { steps: 24 });
    let trace = spec.generate();

    let mut traced_cfg = tiered_cfg();
    traced_cfg.trace = Some(TracerConfig::default());
    let (traced_tokens, tracer) = run(traced_cfg, &trace);

    // (d) observation changes nothing: the untraced twin produces the
    // same tokens, bit for bit, on the deterministic interpreter
    let (untraced_tokens, off) = run(tiered_cfg(), &trace);
    if interpreted() {
        assert_eq!(traced_tokens, untraced_tokens, "tracing must not perturb decoding");
    }
    assert!(!off.enabled(), "trace: None installs the no-op sink");
    assert!(off.events().is_empty());
    assert!(off.plan_vs_actual().is_none());

    let events = tracer.events();
    assert!(!events.is_empty());
    // sequence numbers are the emission order, dense from 0
    for (i, ev) in events.iter().enumerate() {
        assert_eq!(ev.seq, i as u64, "dense emission order");
    }

    // (a) every admitted request carries the complete lifecycle chain,
    // in order — and every submitted request was admitted (the trace
    // retires fully)
    let chains = lifecycles(&events);
    assert_eq!(chains.len(), trace.requests.len(), "one lifecycle per request");
    for (id, c) in &chains {
        let arrive = c.arrive.unwrap_or_else(|| panic!("request {id}: no arrive event"));
        let admit = c.admit.unwrap_or_else(|| panic!("request {id}: no admit event"));
        let first = c.first_token.unwrap_or_else(|| panic!("request {id}: no first-token event"));
        let retire = c.retire.unwrap_or_else(|| panic!("request {id}: no retire event"));
        assert!(
            arrive < admit && admit < first && first < retire,
            "request {id}: lifecycle out of order ({arrive} {admit} {first} {retire})"
        );
    }

    // phase spans stay balanced and properly nested through every early
    // exit of the serving loop
    let mut depth: Vec<Phase> = Vec::new();
    for ev in &events {
        match ev.kind {
            EventKind::PhaseBegin { phase } => depth.push(phase),
            EventKind::PhaseEnd { phase } => {
                assert_eq!(depth.pop(), Some(phase), "mismatched phase end at seq {}", ev.seq);
            }
            _ => {}
        }
    }
    assert!(depth.is_empty(), "unclosed phases: {depth:?}");

    // migration lifecycle: anything that landed was launched first, with
    // identical hop/class/byte tags
    let mut launched: HashMap<u64, (String, String, String, u64)> = HashMap::new();
    let mut landings = 0;
    for ev in &events {
        if let EventKind::Migration { id, phase, ref class, ref from, ref to, bytes } = ev.kind {
            match phase {
                MigPhase::InFlight => {
                    launched.insert(id, (class.clone(), from.clone(), to.clone(), bytes));
                }
                MigPhase::Landed => {
                    landings += 1;
                    let tags = launched
                        .get(&id)
                        .unwrap_or_else(|| panic!("migration {id} landed without launching"));
                    assert_eq!(
                        tags,
                        &(class.clone(), from.clone(), to.clone(), bytes),
                        "migration {id}: tags changed between launch and landing"
                    );
                }
                _ => {}
            }
        }
    }
    assert!(landings > 0, "the tiered host-pressure run must land migrations");

    // (b) per-step grant audit: launched wire bytes stay within the
    // recorded grant, except through the single oversized-launch override
    let records = tracer.step_records();
    assert!(!records.is_empty());
    for r in &records {
        assert!(
            r.launched_wire_bytes <= r.granted_bytes || r.launched == 1,
            "step {}: {} wire bytes launched over a {} grant with {} launches",
            r.step,
            r.launched_wire_bytes,
            r.granted_bytes,
            r.launched
        );
    }

    // (c) plan-vs-actual: residuals finite, summary exported
    for r in &records {
        assert!(r.predicted_s.is_finite() && r.measured_s.is_finite());
        assert!(r.measured_s >= 0.0);
    }
    let pva = tracer.plan_vs_actual().expect("enabled tracer summarises");
    assert_eq!(pva.steps, records.len());
    assert_eq!(pva.residual_s.count(), records.len());
    assert!(pva.residual_s.mean().is_finite());
    assert_eq!(pva.drift_hist.len(), pva.drift_labels().len());
    assert!(!pva.summary_table().is_empty());
    let exported = pva.to_json().to_string();
    let parsed = Json::parse(&exported).expect("summary JSON parses");
    assert!(parsed.get("residual_s").is_some());
}

#[test]
fn chrome_export_is_byte_identical_across_deterministic_replays() {
    let _g = lock();
    if !interpreted() {
        return; // byte-identity is an interpreter-runtime guarantee
    }
    let spec = spec(LenDist::Uniform { lo: 4, hi: 8 });
    let trace = spec.generate();

    let replay = || {
        let mut cfg = continuous_cfg(2, 2);
        cfg.clock = ClockMode::Step { step_s: 0.05 };
        cfg.preload_requests = trace.requests.len();
        cfg.trace = Some(TracerConfig::default());
        let (tokens, tracer) = run(cfg, &trace);
        (chrome_trace(&tracer.events()).to_string(), tokens)
    };
    let (json1, tokens1) = replay();
    let (json2, tokens2) = replay();
    assert_eq!(tokens1, tokens2, "same trace, same tokens, bit for bit");
    assert_eq!(json1, json2, "Chrome export must be byte-identical across replays");

    let parsed = Json::parse(&json1).expect("Chrome trace parses");
    let evs = parsed.get("traceEvents").and_then(|t| t.as_arr()).expect("traceEvents array");
    assert!(!evs.is_empty());
    // the async request spans survive the export: one begin and one end
    // per request, keyed by request id
    for ph in ["b", "e"] {
        let n = evs
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some(ph))
            .count();
        assert_eq!(n, trace.requests.len(), "one {ph:?} event per request");
    }
    // timestamps are monotone within each thread track
    let mut last_ts = f64::NEG_INFINITY;
    for e in evs {
        let ts = e.get("ts").and_then(|t| t.as_f64()).expect("ts");
        assert!(ts >= last_ts, "timestamps must be monotone");
        last_ts = ts;
    }
}
