//! Multi-worker e2e: the sharded [`Router`] front-end over N
//! continuous-batching worker shards.
//!
//! Two properties pin the tentpole claims:
//!
//! * **Placement invariance** — the engine's decode is a deterministic
//!   function of (prompt, generation length), so the same workload trace
//!   served by a 2-shard router must produce bit-identical tokens to a
//!   1-shard run (interpreter runtime; compiled XLA may legally reorder
//!   reductions per bucket, so the cross-shard comparison is pinned only
//!   on the interpreter backend, like every other serving e2e).
//! * **Work stealing is priced, not free** — when a session's affinity
//!   shard saturates, placement steals it to a strictly less-loaded
//!   shard, tags the request with its remote prefix, and the receiving
//!   serve loop parks that prefix on the deep (remote) rung of its
//!   topology chain, where the planner's hop surcharge applies.
//!
//! Like `coordinator_e2e.rs` these need **no artifacts**: without
//! `artifacts/manifest.json` the engine runs the interpreter runtime.

use std::sync::Mutex;
use std::time::Duration;

use kvpr::coordinator::{ContinuousConfig, Router, RouterConfig, Submit, TieredKvConfig};
use kvpr::engine::{EngineConfig, EnginePolicy};
use kvpr::transfer::LinkConfig;
use kvpr::workload::{Arrival, LenDist, SloTargets, Trace, TrafficClass, WorkloadSpec};

/// Serialise the heavy tests: each spins up engine + link worker threads
/// per shard.
static HEAVY: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    HEAVY.lock().unwrap_or_else(|p| p.into_inner())
}

fn interpreted() -> bool {
    !std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json"))
        .exists()
}

fn engine_cfg() -> EngineConfig {
    let mut e = EngineConfig::new(EnginePolicy::Kvpr);
    e.weights_offloaded = true;
    e.link = LinkConfig::with_bandwidth(100e6);
    e.seed = 42;
    e
}

/// Per-shard serving config via the documented builder path; 16-token
/// blocks against a 16-token prompt bucket so a stolen session's remote
/// prefix covers exactly one parkable block.
fn base_cfg() -> ContinuousConfig {
    ContinuousConfig::builder("artifacts", engine_cfg())
        .max_group(2)
        .max_groups(2)
        .prompt_bucket(16)
        .admit_wait(Duration::from_millis(5))
        .kv_budget_bytes(64 << 20)
        .tiering(TieredKvConfig { block_tokens: 16, ..TieredKvConfig::default() })
        .build()
}

/// Six requests in two bursts of three.
fn spec() -> WorkloadSpec {
    WorkloadSpec {
        name: "router_e2e".into(),
        seed: 11,
        requests: 6,
        arrivals: Arrival::Bursty { burst: 3, gap: 2 },
        classes: vec![TrafficClass {
            name: "chat".into(),
            weight: 1.0,
            prompt: LenDist::Fixed { steps: 16 },
            gen: LenDist::Fixed { steps: 8 },
            think: LenDist::Fixed { steps: 0 },
            shared_prefix: 0,
        }],
        slo: SloTargets { ttft_s: 30.0, tpot_s: 30.0 },
    }
}

/// Serve the whole trace through an `shards`-wide router; returns each
/// request's token stream in trace order.
fn run_router(shards: usize, trace: &Trace) -> Vec<Vec<i32>> {
    let router = Router::start(RouterConfig::new(shards, base_cfg())).unwrap();
    assert_eq!(router.n_shards(), shards);
    let handles = router.dispatch(trace);
    let mut tokens = Vec::with_capacity(trace.requests.len());
    for (h, r) in handles.into_iter().zip(&trace.requests) {
        let resp = h.wait().unwrap();
        assert_eq!(resp.tokens.len(), r.gen_tokens, "request {} length", r.id);
        tokens.push(resp.tokens);
    }
    assert_eq!(router.total_requests(), trace.requests.len() as u64);
    assert!(router.total_tokens() > 0);
    router.shutdown().unwrap();
    tokens
}

#[test]
fn two_shard_router_serves_the_trace_bit_identical_to_one_shard() {
    let _g = lock();
    let trace = spec().generate();
    let one = run_router(1, &trace);
    let two = run_router(2, &trace);
    if interpreted() {
        assert_eq!(one, two, "sharded serving changed generated tokens");
    }
}

#[test]
fn saturated_shard_steals_the_session_and_parks_its_remote_prefix() {
    let _g = lock();
    let mut cfg = RouterConfig::new(2, base_cfg());
    cfg.shard_capacity = 1;
    let router = Router::start(cfg).unwrap();
    // one session, submitted back-to-back: its affinity shard saturates at
    // one outstanding request, so placement must shed it to the idle shard
    let prompt = "the session that moves between shards";
    let handles: Vec<_> = (0..6)
        .map(|_| router.dispatch((prompt, 8)).pop().unwrap())
        .collect();
    let mut streams = Vec::new();
    for h in handles {
        streams.push(h.wait().unwrap().tokens);
    }
    let t = router.totals();
    assert_eq!(t.submitted, 6);
    assert_eq!(t.fresh + t.affinity_hits + t.steals, 6);
    assert!(t.steals >= 1, "a saturated affinity shard must shed the session: {t:?}");
    assert!(
        t.remote_prefix_tokens > 0,
        "stolen sessions must carry their remote-prefix tag: {t:?}"
    );
    // the receiving serve loop parked the migrated prefix on its deep
    // (remote) rung — the planner's hop surcharge now prices the re-fetch
    let parked: u64 = (0..router.n_shards())
        .map(|i| router.shard(i).metrics().remote_parked_blocks())
        .sum();
    assert!(parked > 0, "the stolen prefix must be parked on the remote rung");
    // placement moves sessions, never math: every replay of the same
    // prompt decodes the same stream
    for s in &streams[1..] {
        assert_eq!(s, &streams[0], "a stolen session changed generated tokens");
    }
    router.shutdown().unwrap();
}
