//! Engine-level invariants: every policy generates the *same tokens*
//! (the paper's exactness claim), schedules behave as configured, and the
//! engine matches the pure-Rust reference generation.

use std::path::PathBuf;

use kvpr::engine::{Engine, EngineConfig, EnginePolicy};
use kvpr::model::{ByteTokenizer, RefModel};
use kvpr::transfer::LinkConfig;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn fast_cfg(policy: EnginePolicy) -> EngineConfig {
    let mut cfg = EngineConfig::new(policy);
    // fast link so tests don't crawl; correctness is bandwidth-independent
    cfg.link = LinkConfig::with_bandwidth(500e6);
    cfg.seed = 77;
    cfg
}

fn prompts() -> Vec<Vec<i32>> {
    let tok = ByteTokenizer::new();
    vec![
        tok.encode("hello kvpr world", 16),
        tok.encode("partial recomputation", 16),
    ]
}

#[test]
fn all_policies_generate_identical_tokens() {
    let Some(dir) = artifacts() else { return };
    let mut reference: Option<Vec<Vec<i32>>> = None;
    for policy in [
        EnginePolicy::FullTransferSync,
        EnginePolicy::FullTransferOverlap,
        EnginePolicy::Kvpr,
        EnginePolicy::KvprFused,
        EnginePolicy::AlisaSequential,
    ] {
        let engine = Engine::new(&dir, fast_cfg(policy)).unwrap();
        let r = engine.generate(&prompts(), 10).unwrap();
        assert_eq!(r.tokens.len(), 2);
        assert_eq!(r.tokens[0].len(), 10);
        match &reference {
            None => reference = Some(r.tokens),
            Some(want) => assert_eq!(want, &r.tokens, "policy {policy:?} diverged"),
        }
    }
}

#[test]
fn residency_preserves_tokens_exactly() {
    // The device-resident KV suffix (tiered kvstore gpu tier) moves bytes,
    // never math: a session whose window grows, is promoted from host rows
    // and demoted back down mid-decode must emit the same tokens as one
    // without residency.  Runs on the synthetic manifest when no artifacts
    // are present, so it is never skipped.
    let dir = artifacts().unwrap_or_else(|| PathBuf::from("artifacts"));
    let engine = Engine::new(&dir, fast_cfg(EnginePolicy::Kvpr)).unwrap();
    let prompts = prompts();
    const GEN: usize = 24;

    let mut base = engine.start_batch(&prompts).unwrap();
    for _ in 1..GEN {
        engine.decode_step(&mut base).unwrap();
    }
    let base = engine.finish_batch(base);

    let mut sess = engine.start_batch(&prompts).unwrap();
    engine.enable_residency(&mut sess, 8);
    assert_eq!(sess.resident_tokens(), 0);
    for step in 1..GEN {
        if step == 6 {
            // promote the whole cache into the window (host-row copies)
            let kv = sess.kv_len();
            let (promoted, _) = engine.set_resident_target(&mut sess, kv);
            assert!(promoted > 0, "promotion must extend the window");
            assert_eq!(sess.resident_tokens(), kv);
        }
        if step == 12 {
            // demote most of it back down (no writeback needed)
            let (_, demoted) = engine.set_resident_target(&mut sess, 4);
            assert!(demoted > 0);
            assert!(sess.resident_tokens() <= 4);
        }
        engine.decode_step(&mut sess).unwrap();
    }
    assert!(sess.resident_tokens() > 0, "the window grows as tokens append");
    let res = engine.finish_batch(sess);
    assert_eq!(base.tokens, res.tokens, "residency changed generated tokens");
}

#[test]
fn engine_matches_pure_rust_reference() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new(&dir, fast_cfg(EnginePolicy::Kvpr)).unwrap();
    let prompts = prompts();
    let r = engine.generate(&prompts, 8).unwrap();

    // the reference generates with the same weights (same seed) and the
    // same padded prompt layout: batch bucket 4, prompt bucket 16
    let rm = RefModel::new(engine.weights.clone());
    let sp = 16;
    let mut flat = Vec::new();
    for i in 0..4 {
        let src = &prompts[i.min(prompts.len() - 1)];
        for j in 0..sp {
            flat.push(*src.get(j).unwrap_or(&258));
        }
    }
    let want = rm.generate(&flat, 4, sp, 8, 128);
    assert_eq!(r.tokens[0], want[0], "sequence 0");
    assert_eq!(r.tokens[1], want[1], "sequence 1");
}

#[test]
fn kvpr_records_splits_and_baseline_doesnt_recompute() {
    let Some(dir) = artifacts() else { return };
    // slow link → the LP must pick l > 0 once kv_len ≥ smallest bucket;
    // use the 32-token prompt bucket so kv_len starts at a feasible length
    let mut cfg = fast_cfg(EnginePolicy::Kvpr);
    cfg.link = LinkConfig::with_bandwidth(10e6);
    let engine = Engine::new(&dir, cfg).unwrap();
    let tok = ByteTokenizer::new();
    let long_prompts = vec![
        tok.encode("a prompt that pads into the thirty-two bucket", 32),
        tok.encode("another prompt that pads into the same bucket", 32),
    ];
    let r = engine.generate(&long_prompts, 8).unwrap();
    assert_eq!(r.metrics.splits.len(), 7);
    assert!(
        r.metrics.splits.iter().any(|&l| l > 0),
        "KVPR never recomputed on a slow link: {:?}",
        r.metrics.splits
    );
    assert!(r.metrics.breakdown.recompute_s > 0.0);

    let engine = Engine::new(&dir, fast_cfg(EnginePolicy::FullTransferOverlap)).unwrap();
    let r = engine.generate(&prompts(), 8).unwrap();
    assert!(r.metrics.splits.iter().all(|&l| l == 0));
    assert_eq!(r.metrics.breakdown.recompute_s, 0.0);
}

#[test]
fn column_schedule_matches_row_schedule_tokens() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new(&dir, fast_cfg(EnginePolicy::Kvpr)).unwrap();
    let row = engine.generate(&prompts(), 8).unwrap();

    let mut cfg = fast_cfg(EnginePolicy::Kvpr);
    cfg.weights_offloaded = true; // column regime
    let engine = Engine::new(&dir, cfg).unwrap();
    let col = engine
        .generate_column(&[prompts(), prompts()], 8)
        .unwrap();
    assert_eq!(col.len(), 2);
    assert_eq!(col[0].tokens, row.tokens, "group 0");
    assert_eq!(col[1].tokens, row.tokens, "group 1 (same prompts)");
    // weight traffic must have been charged
    assert!(col[0].metrics.breakdown.wait_weights_s >= 0.0);
}

#[test]
fn metrics_are_sane() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new(&dir, fast_cfg(EnginePolicy::Kvpr)).unwrap();
    let r = engine.generate(&prompts(), 6).unwrap();
    let m = &r.metrics;
    assert!(m.prefill_s > 0.0);
    assert!(m.decode_s > 0.0);
    assert_eq!(m.tokens_generated, 2 * 5);
    assert!(m.gpu_peak_bytes > 0);
    assert!(m.h2d_bytes > 0, "decode must move KV bytes");
    let bd_total = m.breakdown.total();
    assert!(bd_total > 0.0 && bd_total <= m.decode_s * 1.5 + m.prefill_s);
    assert!(m.decode_tok_per_s() > 0.0);
}

#[test]
fn fine_grained_weight_pipeline_runs() {
    let Some(dir) = artifacts() else { return };
    let mut cfg = fast_cfg(EnginePolicy::Kvpr);
    cfg.weights_offloaded = true;
    cfg.fine_grained_weights = true;
    cfg.link = LinkConfig::with_bandwidth(50e6);
    let engine = Engine::new(&dir, cfg).unwrap();
    let r = engine.generate(&prompts(), 6).unwrap();
    // weight waits must be accounted and tokens still exact vs non-offloaded
    let engine2 = Engine::new(&dir, fast_cfg(EnginePolicy::Kvpr)).unwrap();
    let r2 = engine2.generate(&prompts(), 6).unwrap();
    assert_eq!(r.tokens, r2.tokens, "offloading must not change tokens");
}

#[test]
fn rejects_oversized_requests() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new(&dir, fast_cfg(EnginePolicy::Kvpr)).unwrap();
    // gen too long for the cache capacity
    assert!(engine.generate(&prompts(), 128).is_err());
    // batch too large for any bucket
    let many: Vec<Vec<i32>> = (0..9).map(|_| vec![1i32; 16]).collect();
    assert!(engine.generate(&many, 4).is_err());
}
