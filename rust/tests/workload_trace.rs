//! Trace-driven sim-vs-served validation: the same seeded workload trace
//! replays through the continuous-batching server
//! ([`Submit::dispatch`]) and the analytic eviction sim
//! ([`EvictionSimConfig::from_trace`]), and the two must agree on the
//! KV traffic the trace implies — generated-token totals exactly, peak
//! KV occupancy within **one request** (the stated tolerance: the sim
//! admits at the top of a round, the serving loop inside a pass, so a
//! retirement racing an arrival can differ by one), and the
//! capacity regime (no reclamation under ample budgets, host overflow
//! under tight ones) in kind.
//!
//! Like `coordinator_e2e.rs` these need **no artifacts**: without
//! `artifacts/manifest.json` the engine runs the interpreter runtime,
//! which is bitwise-deterministic — replaying the identical trace twice
//! must produce bit-identical tokens.

use std::sync::Mutex;
use std::time::Duration;

use kvpr::coordinator::{ContinuousConfig, ContinuousServer, Submit, TieredKvConfig};
use kvpr::engine::{EngineConfig, EnginePolicy};
use kvpr::kvstore::{simulate_eviction, EvictionSimConfig, Lru, RecomputeAware};
use kvpr::scheduler::{CostModel, TierTopology};
use kvpr::transfer::LinkConfig;
use kvpr::workload::{Arrival, LenDist, SloTargets, Trace, TrafficClass, WorkloadSpec};

/// Serialise the heavy tests: each spins up engine + link worker threads.
static HEAVY: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    HEAVY.lock().unwrap_or_else(|p| p.into_inner())
}

const LINK_BPS: f64 = 100e6;

fn engine_cfg() -> EngineConfig {
    let mut e = EngineConfig::new(EnginePolicy::Kvpr);
    e.weights_offloaded = true;
    e.link = LinkConfig::with_bandwidth(LINK_BPS);
    e.seed = 42;
    e
}

fn continuous_cfg(max_group: usize, max_groups: usize) -> ContinuousConfig {
    let mut c = ContinuousConfig::new("artifacts", engine_cfg());
    c.max_group = max_group;
    c.max_groups = max_groups;
    c.prompt_bucket = 16;
    // trace arrivals are step-indexed, not wall-timed: no batching window
    c.admit_wait = Duration::from_millis(1);
    c
}

/// The analytic sim's cost model (same literal the kvstore sim tests
/// use); the agreement asserts here are structural — token totals and
/// occupancy — so the absolute scale never matters.
fn cost() -> CostModel {
    CostModel {
        recompute_per_token_s: 0.3e-6,
        transfer_kv_per_token_s: 1e-6,
        transfer_act_per_token_s: 0.5e-6,
        gpu_overhead_s: 1e-6,
        link_latency_s: 1e-6,
    }
}

/// Six requests in three bursts of two (arrival steps 0,0,3,3,6,6),
/// prompts pinned to the 16-token prompt bucket, short generations.
fn e2e_spec(gen: LenDist) -> WorkloadSpec {
    WorkloadSpec {
        name: "e2e_bursty".into(),
        seed: 17,
        requests: 6,
        arrivals: Arrival::Bursty { burst: 2, gap: 3 },
        classes: vec![TrafficClass {
            name: "chat".into(),
            weight: 1.0,
            prompt: LenDist::Fixed { steps: 16 },
            gen,
            think: LenDist::Fixed { steps: 0 },
            shared_prefix: 0,
        }],
        // generous targets: the debug interpreter's absolute latencies are
        // machine noise; the SLO *counters* are what the test pins
        slo: SloTargets { ttft_s: 30.0, tpot_s: 30.0 },
    }
}

/// What one served replay measured.
struct ServedRun {
    tokens: Vec<Vec<i32>>,
    gen_tokens: u64,
    requests: u64,
    peak_occupancy: f64,
    backpressure: u64,
    kv_dropped: u64,
    spills_issued: u64,
    ttft_p99_s: f64,
    slo_requests: u64,
}

fn run_trace(cfg: ContinuousConfig, trace: &Trace, slo: SloTargets) -> ServedRun {
    let server = ContinuousServer::start(cfg).unwrap();
    server.metrics().set_slo(slo);
    let handles = server.dispatch(trace);
    let mut tokens = Vec::with_capacity(trace.requests.len());
    for (h, r) in handles.into_iter().zip(&trace.requests) {
        let resp = h.wait().unwrap();
        assert_eq!(resp.tokens.len(), r.gen_tokens, "request {} length", r.id);
        tokens.push(resp.tokens);
    }
    let m = server.metrics();
    let out = ServedRun {
        tokens,
        gen_tokens: m.tokens(),
        requests: m.requests(),
        peak_occupancy: m.peak_occupancy(),
        backpressure: m.backpressure_events(),
        kv_dropped: m.tiering_totals().kv_dropped_tokens,
        spills_issued: m.disk_totals().spills_issued,
        ttft_p99_s: m.ttft_stats().p99,
        slo_requests: m.slo_attainment().requests,
    };
    server.shutdown().unwrap();
    out
}

fn interpreted() -> bool {
    !std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json"))
        .exists()
}

#[test]
fn trace_replay_agrees_with_the_analytic_sim_in_the_ample_regime() {
    let _g = lock();
    // Acceptance (tentpole): one seeded trace, two executions — the live
    // continuous-batching loop and the analytic sim share the decode-step
    // clock, so under ample budgets they must agree on the KV traffic.
    let spec = e2e_spec(LenDist::Uniform { lo: 4, hi: 8 });
    let trace = spec.generate();
    assert_eq!(spec.generate(), trace, "generation must be deterministic");
    assert_eq!(
        trace.requests.iter().map(|r| r.step).collect::<Vec<_>>(),
        vec![0, 0, 3, 3, 6, 6],
        "three bursts of two"
    );

    let mk = || {
        let mut cfg = continuous_cfg(2, 4);
        cfg.kv_budget_bytes = 64 << 20; // ample: admission never backpressures
        cfg
    };
    let a = run_trace(mk(), &trace, spec.slo);
    let b = run_trace(mk(), &trace, spec.slo);
    if interpreted() {
        assert_eq!(a.tokens, b.tokens, "same trace, same tokens, bit for bit");
    }

    let sim_cfg = EvictionSimConfig::from_trace(cost(), &trace);
    let sim = simulate_eviction(&sim_cfg, &Lru);

    // KV-traffic agreement: every generated token appends one token of KV
    // in both executions, and both retire the whole trace
    assert_eq!(a.gen_tokens, trace.total_gen_tokens());
    assert_eq!(sim.steps, trace.total_gen_tokens());
    assert_eq!(a.requests, trace.requests.len() as u64);
    assert_eq!(sim.completed, trace.requests.len());

    // KV-occupancy agreement within the stated tolerance of one request
    assert!(
        (sim.peak_concurrency as f64 - a.peak_occupancy).abs() <= 1.0,
        "peak occupancy diverged: sim {} vs served {}",
        sim.peak_concurrency,
        a.peak_occupancy
    );

    // regime agreement: ample budgets reclaim nothing on either side
    assert_eq!(sim.evictions, 0);
    assert_eq!(sim.spills, 0);
    assert!(sim.admit_delay_steps.iter().all(|&d| d == 0), "ample sim admits on arrival");
    assert_eq!(a.backpressure, 0, "ample serving never backpressures");
    assert_eq!(a.kv_dropped, 0);

    // the SLO scorer saw every request, and TTFT percentiles are real
    assert_eq!(a.slo_requests, trace.requests.len() as u64);
    assert!(a.ttft_p99_s > 0.0);
}

#[test]
fn trace_replay_agrees_with_the_analytic_sim_under_host_pressure() {
    let _g = lock();
    // Same harness, tight budgets: a host tier far smaller than the
    // trace's concurrent KV demand must overflow in *both* executions —
    // the served four-tier store spills dram blocks to disk, the sim's
    // four-tier model spills its admission shortfall — and both still
    // retire the whole trace (disk absorbs, nothing deadlocks).
    let spec = e2e_spec(LenDist::Fixed { steps: 24 });
    let trace = spec.generate();

    let mut cfg = continuous_cfg(1, 6);
    cfg.kv_budget_bytes = 200 << 10; // gpu tier: one 16-token block
    cfg.tiering = Some(TieredKvConfig {
        // pinned below one block makes dram the host tier (~10 blocks —
        // one session plus change, against six sessions of demand)
        topology: TierTopology::standard(0, 64 << 10, 2 << 20).with_disk(64 << 20, 0.5),
        block_tokens: 16,
        prefetch_blocks: 1,
        max_inflight: 8,
        promote_cooldown: 2,
        // the tiny full-transfer-bound workload's adaptive grant has no
        // slack; pin the static grant so tier traffic actually flows
        step_budget_override: Some(4 << 20),
        ..TieredKvConfig::default()
    });
    let served = run_trace(cfg, &trace, spec.slo);
    assert_eq!(served.gen_tokens, trace.total_gen_tokens());
    assert_eq!(served.requests, trace.requests.len() as u64);
    assert!(
        served.spills_issued > 0,
        "host pressure must spill dram blocks to disk (issued {})",
        served.spills_issued
    );

    // the analytic twin: same trace, host capacity squeezed to ~40 % of
    // demand, ample disk — the sim must land in the same regime
    let mut sim_cfg = EvictionSimConfig::from_trace(cost(), &trace);
    sim_cfg.disk_bytes = sim_cfg.capacity_bytes * 4;
    sim_cfg.capacity_bytes = sim_cfg.capacity_bytes * 2 / 5;
    let sim = simulate_eviction(&sim_cfg, &RecomputeAware::new(cost()));
    assert_eq!(sim.completed, trace.requests.len(), "disk absorbs the overflow");
    assert_eq!(sim.steps, trace.total_gen_tokens());
    assert!(sim.spills > 0, "the squeezed host budget must spill in the sim too");
}
