//! Pipelined-runtime oracle: [`PipelineMode::Overlapped`] must be a pure
//! wall-clock transformation of [`PipelineMode::Serial`].
//!
//! The overlapped loop changes *where* work happens — the next step's
//! plans are solved by a stage worker inside the compute shadow, group
//! staging double-buffers through the engine's stage/submit split, and
//! the migration pump rides the same shadow — but never *what* the engine
//! computes: an adopted plan is the planner's own solution for the very
//! input the serial path would have solved (validity-token handoff), and
//! plans move bytes, never math.  So across an ample untiered regime and
//! a tight tiered spill regime the two modes must produce bit-identical
//! token streams and identical served-token totals; the only permitted
//! difference is the pipeline telemetry itself.
//!
//! Like `workload_trace.rs` these need **no artifacts**: without
//! `artifacts/manifest.json` the engine runs the bitwise-deterministic
//! interpreter, which is what makes cross-mode token equality a hard
//! assert rather than a statistical one.

use std::sync::Mutex;
use std::time::Duration;

use kvpr::coordinator::{
    ContinuousConfig, ContinuousServer, PipelineMode, PipelineTotals, Submit, TieredKvConfig,
};
use kvpr::engine::{EngineConfig, EnginePolicy};
use kvpr::scheduler::TierTopology;
use kvpr::transfer::LinkConfig;
use kvpr::workload::{Arrival, LenDist, SloTargets, Trace, TrafficClass, WorkloadSpec};

/// Serialise the heavy tests: each spins up engine + link worker threads.
static HEAVY: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    HEAVY.lock().unwrap_or_else(|p| p.into_inner())
}

fn engine_cfg() -> EngineConfig {
    let mut e = EngineConfig::new(EnginePolicy::Kvpr);
    e.weights_offloaded = true;
    e.link = LinkConfig::with_bandwidth(100e6);
    e.seed = 42;
    e
}

fn continuous_cfg(max_group: usize, max_groups: usize) -> ContinuousConfig {
    let mut c = ContinuousConfig::new("artifacts", engine_cfg());
    c.max_group = max_group;
    c.max_groups = max_groups;
    c.prompt_bucket = 16;
    c.admit_wait = Duration::from_millis(1);
    c
}

/// Six requests in three bursts of two (arrival steps 0,0,3,3,6,6).
fn spec(gen: LenDist) -> WorkloadSpec {
    WorkloadSpec {
        name: "pipeline_e2e".into(),
        seed: 17,
        requests: 6,
        arrivals: Arrival::Bursty { burst: 2, gap: 3 },
        classes: vec![TrafficClass {
            name: "chat".into(),
            weight: 1.0,
            prompt: LenDist::Fixed { steps: 16 },
            gen,
            think: LenDist::Fixed { steps: 0 },
            shared_prefix: 0,
        }],
        slo: SloTargets { ttft_s: 30.0, tpot_s: 30.0 },
    }
}

/// The tight tiered regime from `workload_trace.rs`'s host-pressure
/// scenario: a one-block gpu tier over a ~10-block dram tier, disk
/// absorbing the overflow, real migrations and spills every few steps.
fn tiered_cfg() -> ContinuousConfig {
    let mut cfg = continuous_cfg(1, 6);
    cfg.kv_budget_bytes = 200 << 10;
    cfg.tiering = Some(TieredKvConfig {
        topology: TierTopology::standard(0, 64 << 10, 2 << 20).with_disk(64 << 20, 0.5),
        block_tokens: 16,
        prefetch_blocks: 1,
        max_inflight: 8,
        promote_cooldown: 2,
        step_budget_override: Some(4 << 20),
        ..TieredKvConfig::default()
    });
    cfg
}

/// What one served replay produced, per mode.
struct Run {
    tokens: Vec<Vec<i32>>,
    token_total: u64,
    requests: u64,
    pipeline: PipelineTotals,
}

fn run(mut cfg: ContinuousConfig, mode: PipelineMode, trace: &Trace) -> Run {
    cfg.pipeline = mode;
    let server = ContinuousServer::start(cfg).unwrap();
    let handles = server.dispatch(trace);
    let mut tokens = Vec::with_capacity(trace.requests.len());
    for (h, r) in handles.into_iter().zip(&trace.requests) {
        let resp = h.wait().unwrap();
        assert_eq!(resp.tokens.len(), r.gen_tokens, "request {} length", r.id);
        tokens.push(resp.tokens);
    }
    let m = server.metrics();
    let out = Run {
        tokens,
        token_total: m.tokens(),
        requests: m.requests(),
        pipeline: m.pipeline_totals(),
    };
    server.shutdown().unwrap();
    out
}

fn interpreted() -> bool {
    !std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json"))
        .exists()
}

/// The cross-mode oracle shared by both regimes.
fn assert_modes_agree(serial: &Run, over: &Run, regime: &str) {
    assert_eq!(
        serial.token_total, over.token_total,
        "{regime}: served-token totals must match across pipeline modes"
    );
    assert_eq!(serial.requests, over.requests, "{regime}: request totals must match");
    if interpreted() {
        assert_eq!(
            serial.tokens, over.tokens,
            "{regime}: overlapped tokens must be bit-identical to serial"
        );
    }
    assert_eq!(
        serial.pipeline,
        PipelineTotals::default(),
        "{regime}: serial mode must never touch the pipeline counters"
    );
    assert!(over.pipeline.steps > 0, "{regime}: overlapped mode must count its steps");
}

#[test]
fn overlapped_matches_serial_in_the_ample_regime() {
    let _g = lock();
    let spec = spec(LenDist::Fixed { steps: 24 });
    let trace = spec.generate();
    let mk = || {
        let mut cfg = continuous_cfg(2, 2);
        cfg.kv_budget_bytes = 64 << 20; // ample: admission never backpressures
        cfg
    };
    let serial = run(mk(), PipelineMode::Serial, &trace);
    let over = run(mk(), PipelineMode::Overlapped, &trace);
    assert_modes_agree(&serial, &over, "ample");

    // untiered steady decode is the best case for the prestage worker:
    // between admissions and retirements every projected input matches,
    // so whole steps run fully prestaged and plans are adopted unchanged
    let p = over.pipeline;
    assert!(p.plans_adopted > 0, "steady decode must redeem prestaged plans ({p:?})");
    assert!(p.prestaged_steps > 0, "some steps must run fully prestaged ({p:?})");
    assert!(p.prestaged_steps <= p.steps, "prestaged steps exceed pipeline steps ({p:?})");
}

#[test]
fn overlapped_matches_serial_under_tiered_host_pressure() {
    let _g = lock();
    let spec = spec(LenDist::Fixed { steps: 24 });
    let trace = spec.generate();
    let serial = run(tiered_cfg(), PipelineMode::Serial, &trace);
    let over = run(tiered_cfg(), PipelineMode::Overlapped, &trace);
    assert_modes_agree(&serial, &over, "tiered");

    // under migration churn the projected inputs go stale: placement
    // moves between prestage and redemption, and every such step books a
    // counted fallback re-solve instead of executing a stale plan
    let p = over.pipeline;
    assert!(
        p.plans_adopted + p.fallback_resolves > 0,
        "tiered steps must plan through the handoff ({p:?})"
    );
}
