//! Coordinator end-to-end: requests through admission → per-batch split
//! planning → decode → retirement, for both serving modes (continuous
//! batching and the whole-batch baseline), plus the data-parallel router.
//!
//! These tests need **no artifacts**: when `artifacts/manifest.json` is
//! absent the engine runs on the interpreter runtime over a synthetic
//! manifest, so the full serving stack is exercised in any container.
//!
//! The headline test drives ≥ 8 concurrent requests through the
//! continuous-batching loop and checks its measured throughput beats the
//! no-batching (one-request-at-a-time) configuration of the *same* loop on
//! the same emulated hardware — and that the discrete-event simulator
//! parameterised with that hardware predicts the same ordering.

use std::sync::Mutex;
use std::time::Duration;

use kvpr::config::{HardwareConfig, ModelConfig, Objective, WorkloadConfig};
use kvpr::coordinator::{
    Batcher, ContinuousConfig, ContinuousServer, DiskTotals, Request, Router, RouterConfig, Server,
    ServerConfig, Submit, TieredKvConfig,
};
use kvpr::engine::{EngineConfig, EnginePolicy};
use kvpr::scheduler::TierTopology;
use kvpr::sim::{simulate_decode, Policy, RunConfig};
use kvpr::transfer::LinkConfig;

/// Serialise the heavy tests: each spins up engine + link worker threads,
/// and the throughput comparison is wall-clock sensitive on small boxes.
static HEAVY: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    HEAVY.lock().unwrap_or_else(|p| p.into_inner())
}

const LINK_BPS: f64 = 100e6;

/// Engine in the throughput (weights-offloaded) regime: per-step weight
/// traffic is what continuous batching amortises across concurrent
/// requests, exactly like the paper's column-by-column schedule.
fn engine_cfg() -> EngineConfig {
    let mut e = EngineConfig::new(EnginePolicy::Kvpr);
    e.weights_offloaded = true;
    e.link = LinkConfig::with_bandwidth(LINK_BPS);
    e.seed = 42;
    e
}

fn continuous_cfg(max_group: usize, max_groups: usize) -> ContinuousConfig {
    let mut c = ContinuousConfig::new("artifacts", engine_cfg());
    c.max_group = max_group;
    c.max_groups = max_groups;
    c.prompt_bucket = 16;
    c.admit_wait = Duration::from_millis(150);
    c
}

fn prompts(n: usize) -> Vec<String> {
    (0..n)
        .map(|i| {
            [
                "the quick brown fox",
                "kv cache partial recomputation",
                "pcie is the bottleneck",
                "overlap compute and transfer",
            ][i % 4]
                .to_string()
        })
        .collect()
}

/// Run `n` requests through a continuous server; returns (tokens per
/// request, measured tokens/s over the run's wall time).
fn drive(cfg: ContinuousConfig, n: usize, gen_len: usize) -> (Vec<Vec<i32>>, f64) {
    let server = ContinuousServer::start(cfg).unwrap();
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = prompts(n)
        .iter()
        .map(|p| server.dispatch((p.as_str(), gen_len)).pop().unwrap())
        .collect();
    let mut tokens = Vec::with_capacity(n);
    for h in handles {
        let r = h.wait().unwrap();
        assert_eq!(r.tokens.len(), gen_len);
        assert!(r.total_s > 0.0);
        tokens.push(r.tokens);
    }
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(server.metrics().requests(), n as u64);
    let tput = (n * gen_len) as f64 / wall;
    server.shutdown().unwrap();
    (tokens, tput)
}

#[test]
fn continuous_batching_beats_serial_and_matches_sim_prediction() {
    let _g = lock();
    const N: usize = 8;
    const GEN: usize = 4;

    // ≥ 8 concurrent requests through one continuous group
    let batched_server_cfg = continuous_cfg(N, 2);
    let (tok_batched, tput_batched) = drive(batched_server_cfg, N, GEN);

    // the no-batching baseline: same loop, same engine, one request at a time
    let mut serial_cfg = continuous_cfg(1, 1);
    serial_cfg.admit_wait = Duration::from_millis(1);
    let (tok_serial, tput_serial) = drive(serial_cfg, N, GEN);

    // exactness first: batching must not change a single token.  The
    // interpreter is bitwise-deterministic across batch buckets; compiled
    // XLA may legally reorder reductions per bucket, so the cross-bucket
    // comparison is pinned only on the interpreter backend.
    let interpreted = !std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json")).exists();
    if interpreted {
        assert_eq!(
            tok_batched, tok_serial,
            "continuous batching changed generated tokens"
        );
    }

    // the simulator, parameterised with the same emulated hardware, must
    // predict that batching raises throughput in this regime...
    let hw = HardwareConfig {
        name: "local-e2e".into(),
        pcie_bytes_per_sec: LINK_BPS,
        pcie_latency_s: 30e-6,
        gpu_peak_flops: 2e8, // debug-build interpreter ballpark
        gpu_efficiency: 1.0,
        gpu_launch_overhead_s: 1e-4,
        gpu_mem_bytes: 2 << 30,
        cpu_flops: 1e9,
        cpu_mem_bytes: 8 << 30,
    };
    let wl = |batch: usize| WorkloadConfig {
        objective: Objective::Throughput,
        batch,
        n_batches: 1,
        prompt_len: 16,
        gen_len: GEN,
        weights_offloaded: true,
        kv_quant_4bit: false,
    };
    let sim8 = simulate_decode(&RunConfig::new(
        ModelConfig::tiny(),
        hw.clone(),
        wl(8),
        Policy::Kvpr,
    ));
    let sim1 = simulate_decode(&RunConfig::new(ModelConfig::tiny(), hw, wl(1), Policy::Kvpr));
    assert!(
        sim8.tok_per_s > sim1.tok_per_s,
        "sim must predict batching wins: {} vs {}",
        sim8.tok_per_s,
        sim1.tok_per_s
    );

    // ...and the measured system must agree with the prediction
    assert!(
        tput_batched > tput_serial,
        "continuous batching did not beat serial: {tput_batched:.1} vs {tput_serial:.1} tok/s"
    );
}

#[test]
fn continuous_loop_counts_steps_and_occupancy() {
    let _g = lock();
    const N: usize = 8;
    const GEN: usize = 4;
    let server = ContinuousServer::start(continuous_cfg(N, 2)).unwrap();
    let handles: Vec<_> = prompts(N)
        .iter()
        .map(|p| server.dispatch((p.as_str(), GEN)).pop().unwrap())
        .collect();
    for h in handles {
        h.wait().unwrap();
    }
    let m = server.metrics();
    assert_eq!(m.requests(), N as u64);
    assert!(m.steps() >= (GEN - 1) as u64, "steps {}", m.steps());
    // the admit window gathers the burst into one wide group: concurrency
    // must actually have happened
    assert!(
        m.mean_occupancy() >= 4.0,
        "requests were not decoded concurrently (mean occupancy {})",
        m.mean_occupancy()
    );
    let (mean_step, p99_step) = m.step_stats();
    assert!(mean_step > 0.0 && p99_step >= mean_step);
    assert!(m.step_tok_per_s() > 0.0);
    server.shutdown().unwrap();
}

#[test]
fn continuous_loop_retires_members_independently() {
    let _g = lock();
    // two requests share one group but want different generation lengths:
    // the short one must retire (and be answered) with exactly its budget,
    // while the long one keeps decoding
    let server = ContinuousServer::start(continuous_cfg(2, 1)).unwrap();
    let h_short = server.dispatch(("short request", 3)).pop().unwrap();
    let h_long = server.dispatch(("long request please", 9)).pop().unwrap();
    let r_short = h_short.wait().unwrap();
    let r_long = h_long.wait().unwrap();
    assert_eq!(r_short.tokens.len(), 3);
    assert_eq!(r_long.tokens.len(), 9);
    let m = server.metrics();
    assert_eq!(m.requests(), 2);
    // after the short request retires, steps run below full occupancy
    assert!(m.mean_occupancy() < 2.0, "occupancy {}", m.mean_occupancy());
    server.shutdown().unwrap();
}

#[test]
fn kv_budget_backpressure_serialises_admission() {
    let _g = lock();
    // budget fits exactly one single-lane session (tiny: 4 layers × 3
    // tensors × 128 rows × 256 hidden × 4 B ≈ 1.5 MiB) — concurrent
    // requests must queue behind the budget, not crash
    let mut cfg = continuous_cfg(1, 4);
    cfg.kv_budget_bytes = 2 << 20;
    cfg.admit_wait = Duration::from_millis(1);
    let server = ContinuousServer::start(cfg).unwrap();
    let handles: Vec<_> = prompts(3)
        .iter()
        .map(|p| server.dispatch((p.as_str(), 3)).pop().unwrap())
        .collect();
    for h in handles {
        let r = h.wait().unwrap();
        assert_eq!(r.tokens.len(), 3);
    }
    let m = server.metrics();
    assert_eq!(m.requests(), 3);
    assert!(
        m.backpressure_events() > 0,
        "expected KV-budget backpressure with a one-session budget"
    );
    server.shutdown().unwrap();
}

#[test]
fn tiered_kvstore_admits_more_than_hard_backpressure() {
    let _g = lock();
    // Acceptance: under the same gpu-hbm budget, the tiered kvstore admits
    // strictly more concurrent requests than PR 1's hard backpressure —
    // and decoding stays bit-identical.  Budget fits exactly one
    // single-lane session (tiny model: 4 layers × 3 tensors × 128 rows ×
    // 256 hidden × 4 B ≈ 1.5 MiB).
    const N: usize = 4;
    const GEN: usize = 4;
    let mk = |tiered: bool| {
        let mut cfg = continuous_cfg(1, 4);
        cfg.kv_budget_bytes = 2 << 20;
        cfg.admit_wait = Duration::from_millis(1);
        if tiered {
            cfg.tiering = Some(TieredKvConfig {
                // pin the PR 4 static grant: this test's "gpu tier carried
                // KV" assertion needs promotions to land within a short
                // 4-token run, not the adaptive trickle a zero-slack
                // workload grants (covered by its own e2e)
                step_budget_override: Some(4 << 20),
                ..TieredKvConfig::default()
            });
        }
        cfg
    };

    // PR 1 baseline: the budget serialises admission
    let server = ContinuousServer::start(mk(false)).unwrap();
    let handles: Vec<_> = prompts(N)
        .iter()
        .map(|p| server.dispatch((p.as_str(), GEN)).pop().unwrap())
        .collect();
    let mut base_tokens = Vec::new();
    for h in handles {
        base_tokens.push(h.wait().unwrap().tokens);
    }
    let base_peak = server.metrics().peak_occupancy();
    assert!(server.metrics().backpressure_events() > 0, "budget must bind");
    server.shutdown().unwrap();
    assert!(base_peak <= 1.0 + 1e-9, "baseline must serialise: peak {base_peak}");

    // tiered: same gpu-hbm budget, admission against pinned+dram capacity,
    // async prefetch + device-resident suffix active
    let server = ContinuousServer::start(mk(true)).unwrap();
    let handles: Vec<_> = prompts(N)
        .iter()
        .map(|p| server.dispatch((p.as_str(), GEN)).pop().unwrap())
        .collect();
    let mut tiered_tokens = Vec::new();
    for h in handles {
        tiered_tokens.push(h.wait().unwrap().tokens);
    }
    let tiered_peak = server.metrics().peak_occupancy();
    let promoted = server.metrics().tiering_totals().promoted_tokens;
    server.shutdown().unwrap();

    assert!(
        tiered_peak > base_peak,
        "tiering must admit strictly more concurrent requests: {tiered_peak} vs {base_peak}"
    );
    assert_eq!(base_tokens, tiered_tokens, "tiered serving changed tokens");
    // the gpu tier actually carried KV (residency/prefetch was exercised)
    assert!(promoted > 0, "no tokens were ever promoted into the gpu tier");
}

#[test]
fn async_demotions_drain_a_full_gpu_tier_across_steps() {
    let _g = lock();
    // Acceptance (PR 3): the serving path never waits on the migration
    // link.  A gpu tier far smaller than the concurrent residency demand
    // (4 groups × 2 blocks vs ~5 block slots) forces evictions; those must
    // surface as *asynchronous* demotions — issued on one step (gpu bytes
    // free instantly), their writebacks polled in on later steps — while
    // decoding stays bit-identical to the untiered baseline.
    const N: usize = 4;
    const GEN: usize = 10;
    let mk = |tiered: bool| {
        let mut cfg = continuous_cfg(1, 4);
        // tiered: the budget is the gpu *tier* — ~5 blocks of 16 tokens,
        // against 4 groups × 2 valid blocks of residency demand.  The
        // baseline needs a budget one whole session fits (~1.5 MiB).
        cfg.kv_budget_bytes = if tiered { 1 << 20 } else { 2 << 20 };
        cfg.admit_wait = Duration::from_millis(1);
        if tiered {
            cfg.tiering = Some(TieredKvConfig {
                block_tokens: 16,
                prefetch_blocks: 2,
                max_inflight: 16,
                promote_cooldown: 2,
                // this test is about migration *flow* (demotions issued one
                // step, polled on later ones), so pin the PR 4 static grant;
                // the adaptive grant has its own e2e below
                step_budget_override: Some(4 << 20),
                ..TieredKvConfig::default()
            });
        }
        cfg
    };

    let (base_tokens, _) = drive(mk(false), N, GEN);

    let server = ContinuousServer::start(mk(true)).unwrap();
    let handles: Vec<_> = prompts(N)
        .iter()
        .map(|p| server.dispatch((p.as_str(), GEN)).pop().unwrap())
        .collect();
    let mut tiered_tokens = Vec::new();
    for h in handles {
        tiered_tokens.push(h.wait().unwrap().tokens);
    }
    let m = server.metrics();
    let mig = m.migration_totals();
    let (launched, landed) = (mig.launched, mig.landed);
    let dem = m.demotion_totals();
    let (dem_issued, dem_polled) = (dem.issued, dem.polled);
    server.shutdown().unwrap();

    assert!(launched > 0, "migrations must have launched under the step budget");
    assert!(landed > 0, "migrations must have been polled in on later steps");
    assert!(
        dem_issued > 0,
        "a gpu tier smaller than the residency demand must evict asynchronously"
    );
    assert!(
        dem_polled > 0,
        "demotion writebacks must land via polling, never a blocking wait \
         (issued {dem_issued}, polled {dem_polled})"
    );
    let interpreted = !std::path::Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/artifacts/manifest.json"
    ))
    .exists();
    if interpreted {
        assert_eq!(base_tokens, tiered_tokens, "async demotions changed generated tokens");
    }
}

#[test]
fn disk_spill_admits_more_sequences_and_never_blocks_the_step_loop() {
    let _g = lock();
    // Acceptance (PR 4): with a dram budget too small for the offered
    // load, spill-enabled four-tier serving admits strictly more
    // concurrent sequences than the PR 3 three-tier config, produces
    // bit-identical tokens, and the step loop never blocks on a disk
    // transfer — every disk byte is issued and completed through the
    // MigrationEngine's poll path (issued on one step, polled on later
    // ones).
    //
    // Two waves make the spill path deterministic: one long request fills
    // the dram tier and decodes until its prefix blocks are fully valid
    // (the one-block gpu tier cannot absorb them), then three more
    // requests arrive.  Three-tier: the dram budget serialises the wave.
    // Four-tier: the mature prefix blocks spill to disk and the wave's
    // own cold blocks park there, so everything decodes concurrently.
    const GEN_LONG: usize = 60;
    const GEN_SHORT: usize = 6;
    let mk = |disk_bytes: u64| {
        let mut cfg = continuous_cfg(1, 4);
        cfg.kv_budget_bytes = 200 << 10; // gpu tier: one 16-token block
        cfg.admit_wait = Duration::from_millis(1);
        cfg.tiering = Some(TieredKvConfig {
            // gpu rung 0 inherits the serving budget; pinned below one
            // block makes dram the host tier (~10 blocks: one session
            // plus change); a zero-capacity disk rung keeps three tiers
            topology: TierTopology::standard(0, 64 << 10, 2 << 20).with_disk(disk_bytes, 0.5),
            block_tokens: 16,
            prefetch_blocks: 1,
            max_inflight: 8,
            promote_cooldown: 2,
            // spill is strictly leftover-budget traffic, which the tiny
            // full-transfer-bound workload's adaptive grant never has —
            // this test pins the PR 4 static grant to exercise the spill
            // machinery itself
            step_budget_override: Some(4 << 20),
            ..TieredKvConfig::default()
        });
        cfg
    };
    let run = |cfg: ContinuousConfig| {
        let server = ContinuousServer::start(cfg).unwrap();
        let long = server.dispatch(("the long running sequence", GEN_LONG)).pop().unwrap();
        // wave 2 arrives once the long group's prefix blocks are mature
        // (kv ≥ 32 tokens ⇒ a fully-valid dram block exists)
        for _ in 0..2000 {
            if server.metrics().steps() >= 20 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        let wave: Vec<_> = ["wave two b", "wave two c", "wave two d"]
            .iter()
            .map(|p| server.dispatch((*p, GEN_SHORT)).pop().unwrap())
            .collect();
        let mut tokens = vec![long.wait().unwrap().tokens];
        for h in wave {
            tokens.push(h.wait().unwrap().tokens);
        }
        let m = server.metrics();
        let out = (tokens, m.peak_occupancy(), m.disk_totals(), m.backpressure_events());
        server.shutdown().unwrap();
        out
    };

    let (tok3, peak3, disk3, bp3) = run(mk(0));
    assert_eq!(disk3, DiskTotals::default(), "no disk tier, no disk traffic");
    assert!(bp3 > 0, "the dram budget must bind in the three-tier run");
    assert!(peak3 <= 1.0 + 1e-9, "three-tier must serialise the wave: peak {peak3}");

    let (tok4, peak4, disk4, _) = run(mk(64 << 20));
    let (sp_issued, sp_polled) = (disk4.spills_issued, disk4.spills_polled);
    let (hop_issued, hop_polled) = (disk4.hops_issued, disk4.hops_polled);
    assert!(
        peak4 > peak3,
        "spill-enabled serving must admit strictly more concurrent sequences: \
         {peak4} vs {peak3}"
    );
    assert!(sp_issued > 0, "dram pressure must spill cold blocks to disk");
    assert!(
        sp_polled > 0,
        "spill writebacks must land via polling on later steps, never a blocking \
         wait (issued {sp_issued}, polled {sp_polled})"
    );
    // Disk *reads* (two-hop promotions) depend on gpu-tier timing and are
    // not guaranteed to trigger here; their issued-one-step /
    // polled-a-later-step staging is pinned deterministically by
    // kvstore::store::tests::two_hop_promotion_stages_across_steps.  This
    // run only checks consistency if any occurred.
    assert!(hop_polled <= hop_issued, "hops cannot land more often than issued");
    let interpreted = !std::path::Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/artifacts/manifest.json"
    ))
    .exists();
    if interpreted {
        assert_eq!(tok3, tok4, "disk spill changed generated tokens");
    }
}

#[test]
fn adaptive_step_budget_tracks_planner_slack() {
    let _g = lock();
    // Acceptance (PR 5): the migration engine's per-step grant is derived
    // from the planner's predicted idle-link slack
    // (StepPlan::link_slack_bytes) — the static step_link_budget_bytes
    // knob is gone.  Drive the same tiered workload twice, adaptive and
    // with a pinned static override, and check:
    //  * adaptive: every step's grant is exactly max(slack, 1) — the
    //    per-step mismatch counter stays 0 and the aggregate identity
    //    granted == slack + zero_slack_steps holds;
    //  * zero-slack steps (full-transfer plans keep the wire busy end to
    //    end; this tiny workload is all zero-slack) launch at most one
    //    migration — only the engine's progress-guarantee override fires;
    //  * the two runs decode bit-identical tokens (the budget policy
    //    moves bytes and schedules, never the math).
    const N: usize = 4;
    const GEN: usize = 10;
    let mk = |override_bytes: Option<u64>| {
        let mut cfg = continuous_cfg(1, 4);
        cfg.kv_budget_bytes = 1 << 20;
        cfg.admit_wait = Duration::from_millis(1);
        cfg.tiering = Some(TieredKvConfig {
            block_tokens: 16,
            prefetch_blocks: 2,
            max_inflight: 16,
            promote_cooldown: 2,
            step_budget_override: override_bytes,
            ..TieredKvConfig::default()
        });
        cfg
    };
    let run = |cfg: ContinuousConfig| {
        let server = ContinuousServer::start(cfg).unwrap();
        let handles: Vec<_> = prompts(N)
            .iter()
            .map(|p| server.dispatch((p.as_str(), GEN)).pop().unwrap())
            .collect();
        let mut tokens = Vec::new();
        for h in handles {
            tokens.push(h.wait().unwrap().tokens);
        }
        let budget = server.metrics().budget_totals();
        let launched = server.metrics().migration_totals().launched;
        server.shutdown().unwrap();
        (tokens, budget, launched)
    };

    let (tok_adaptive, b, launched) = run(mk(None));
    assert!(b.steps > 0, "the tiered loop must have granted budgets");
    assert_eq!(
        b.mismatch_steps, 0,
        "every adaptive grant must be max(slack, 1): {b:?}"
    );
    assert_eq!(
        b.granted_bytes,
        b.slack_bytes + b.zero_slack_steps,
        "the grant must track the plans' slack byte-for-byte: {b:?}"
    );
    assert!(b.zero_slack_steps > 0, "full-transfer plans must predict zero slack");
    assert!(
        b.zero_slack_launch_max <= 1,
        "zero slack ⇒ only the progress-guarantee override may fire: {b:?}"
    );
    assert!(launched > 0, "migrations must still flow under the adaptive grant");

    // A/B: the pinned static grant (the retired knob's behavior)
    let (tok_static, b_static, _) = run(mk(Some(4 << 20)));
    assert!(
        b_static.mismatch_steps > 0,
        "the override must detach the grant from the slack: {b_static:?}"
    );
    let interpreted = !std::path::Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/artifacts/manifest.json"
    ))
    .exists();
    if interpreted {
        assert_eq!(
            tok_adaptive, tok_static,
            "the budget policy changed generated tokens"
        );
    }
}

// ---------------------------------------------------------------------------
// whole-batch baseline server + router (previously artifact-gated; the
// interpreter runtime makes them unconditional)
// ---------------------------------------------------------------------------

fn scfg() -> ServerConfig {
    let mut ecfg = EngineConfig::new(EnginePolicy::Kvpr);
    ecfg.link = LinkConfig::with_bandwidth(500e6);
    let mut cfg = ServerConfig::new("artifacts", ecfg);
    cfg.batcher = Batcher::new(4, Duration::from_millis(10));
    cfg.prompt_bucket = 16;
    cfg
}

#[test]
fn batch_server_serves_batched_requests() {
    let _g = lock();
    let server = Server::start(scfg()).unwrap();
    let handles: Vec<_> = (0..4)
        .map(|i| server.dispatch((format!("request number {i}"), 6)).pop().unwrap())
        .collect();
    for h in handles {
        let r = h.wait().unwrap();
        assert_eq!(r.tokens.len(), 6);
        assert!(r.total_s > 0.0);
        assert!(r.decode_s > 0.0);
    }
    assert_eq!(server.metrics().requests(), 4);
    assert_eq!(server.metrics().tokens(), 24);
    server.shutdown().unwrap();
}

#[test]
fn same_prompt_same_tokens_across_serving_modes() {
    let _g = lock();
    // batch server and continuous server must decode identically: the
    // serving loop moves bytes and schedules, never the math
    let server = Server::start(scfg()).unwrap();
    let ha = server.dispatch(("determinism", 6)).pop().unwrap();
    let a = ha.wait().unwrap();
    let hb = server.dispatch(("determinism", 6)).pop().unwrap();
    let b = hb.wait().unwrap();
    assert_eq!(a.tokens, b.tokens, "same prompt must decode identically");
    server.shutdown().unwrap();

    let mut ccfg = continuous_cfg(1, 1);
    ccfg.engine = scfg().engine;
    let cont = ContinuousServer::start(ccfg).unwrap();
    let hc = cont.dispatch(("determinism", 6)).pop().unwrap();
    let c = hc.wait().unwrap();
    assert_eq!(a.tokens, c.tokens, "continuous loop diverged from batch server");
    cont.shutdown().unwrap();
}

#[test]
fn batch_server_truncates_to_requested_gen_len() {
    let _g = lock();
    let mut cfg = scfg();
    cfg.batcher = Batcher::new(2, Duration::from_millis(200));
    let server = Server::start(cfg).unwrap();
    // two requests with different gen lengths share a batch; the shorter
    // one is truncated on return
    let h1 = server.dispatch(("short one", 3)).pop().unwrap();
    let h2 = server.dispatch(("long one", 8)).pop().unwrap();
    let r1 = h1.wait().unwrap();
    let r2 = h2.wait().unwrap();
    assert_eq!(r1.tokens.len(), 3);
    assert_eq!(r2.tokens.len(), 8);
    server.shutdown().unwrap();
}

#[test]
fn sharded_router_serves_across_two_shards() {
    let _g = lock();
    // the sharded Router spreads fresh sessions by outstanding load; four
    // distinct prompts submitted back-to-back must touch both shards
    let mut base = continuous_cfg(2, 2);
    base.admit_wait = Duration::from_millis(5);
    let router = Router::start(RouterConfig::new(2, base)).unwrap();
    assert_eq!(router.n_shards(), 2);
    let handles: Vec<_> = (0..4)
        .map(|i| router.dispatch((format!("r{i}"), 4)).pop().unwrap())
        .collect();
    for h in handles {
        let r = h.wait().unwrap();
        assert_eq!(r.tokens.len(), 4);
    }
    let t = router.totals();
    assert_eq!(t.submitted, 4);
    assert_eq!(t.fresh + t.affinity_hits + t.steals, 4);
    assert_eq!(router.total_requests(), 4);
    // both shards must have seen traffic
    assert!(router.shard(0).metrics().requests() > 0);
    assert!(router.shard(1).metrics().requests() > 0);
    router.shutdown().unwrap();
}

#[test]
fn dispatch_accepts_every_retired_shim_shape() {
    let _g = lock();
    // satellite: the deprecated submit/submit_request shims are deleted —
    // `Submit::dispatch` is the one front door, and every input shape the
    // shims used to accept (prompt + gen, an explicit Request) must route
    // through it to identical tokens
    let server = ContinuousServer::start(continuous_cfg(2, 1)).unwrap();
    let via_pair = server.dispatch(("shim equivalence", 5)).pop().unwrap();
    let via_pair = via_pair.wait().unwrap();
    let via_request = server
        .dispatch(Request::new(9001, "shim equivalence", 5))
        .pop()
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(via_request.tokens, via_pair.tokens, "Request dispatch diverged");
    server.shutdown().unwrap();
}
