//! Coordinator end-to-end: requests through server → batcher → engine,
//! and the data-parallel router.

use std::time::Duration;

use kvpr::coordinator::{Batcher, Router, Server, ServerConfig};
use kvpr::engine::{EngineConfig, EnginePolicy};
use kvpr::transfer::LinkConfig;

fn scfg() -> Option<ServerConfig> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        return None;
    }
    let mut ecfg = EngineConfig::new(EnginePolicy::Kvpr);
    ecfg.link = LinkConfig::with_bandwidth(500e6);
    let mut cfg = ServerConfig::new(dir.to_str().unwrap(), ecfg);
    cfg.batcher = Batcher::new(4, Duration::from_millis(10));
    Some(cfg)
}

#[test]
fn serves_batched_requests() {
    let Some(cfg) = scfg() else { return };
    let server = Server::start(cfg).unwrap();
    let handles: Vec<_> = (0..4)
        .map(|i| server.submit(&format!("request number {i}"), 6))
        .collect();
    for h in handles {
        let r = h.wait().unwrap();
        assert_eq!(r.tokens.len(), 6);
        assert!(r.total_s > 0.0);
        assert!(r.decode_s > 0.0);
    }
    assert_eq!(server.metrics().requests(), 4);
    // 4 requests with batch limit 4 and same instant → ideally one batch
    assert!(server.metrics().batches() <= 2);
    assert_eq!(server.metrics().tokens(), 24);
    server.shutdown().unwrap();
}

#[test]
fn same_prompt_same_tokens_across_batches() {
    let Some(cfg) = scfg() else { return };
    let server = Server::start(cfg).unwrap();
    let a = server.submit("determinism", 6).wait().unwrap();
    let b = server.submit("determinism", 6).wait().unwrap();
    assert_eq!(a.tokens, b.tokens, "same prompt must decode identically");
    server.shutdown().unwrap();
}

#[test]
fn truncates_to_requested_gen_len() {
    let Some(mut cfg) = scfg() else { return };
    cfg.batcher = Batcher::new(2, Duration::from_millis(200));
    let server = Server::start(cfg).unwrap();
    // two requests with different gen lengths share a batch; the shorter
    // one is truncated on return
    let h1 = server.submit("short one", 3);
    let h2 = server.submit("long one", 8);
    let r1 = h1.wait().unwrap();
    let r2 = h2.wait().unwrap();
    assert_eq!(r1.tokens.len(), 3);
    assert_eq!(r2.tokens.len(), 8);
    server.shutdown().unwrap();
}

#[test]
fn router_round_robins_two_workers() {
    let Some(cfg) = scfg() else { return };
    let router = Router::start(&cfg, 2).unwrap();
    assert_eq!(router.n_servers(), 2);
    let handles: Vec<_> = (0..4).map(|i| router.submit(&format!("r{i}"), 4)).collect();
    for h in handles {
        h.wait().unwrap();
    }
    assert_eq!(router.total_requests(), 4);
    // both workers must have seen traffic
    assert!(router.server(0).metrics().requests() > 0);
    assert!(router.server(1).metrics().requests() > 0);
    router.shutdown().unwrap();
}
