//! Cross-request prefix sharing end-to-end: at the same tier budgets,
//! sharing-enabled admission fits strictly more concurrent sequences and
//! launches strictly fewer migration wire bytes than private admission —
//! while generated tokens stay bit-identical, because the registry is an
//! accounting layer (it moves reservations, never math).  Also pins the
//! physical dropped-KV reclamation satellite: truncating a dropped prefix
//! frees real host bytes and the mandatory recompute floor keeps decode
//! exact.

use std::path::PathBuf;
use std::time::Duration;

use kvpr::coordinator::{ContinuousConfig, ContinuousServer, Submit};
use kvpr::engine::{Engine, EngineConfig, EnginePolicy};
use kvpr::kvstore::{KvStore, KvStoreConfig, Lru, MigrationClass};
use kvpr::transfer::LinkConfig;

const BT: usize = 16; // block tokens
const BB: u64 = 4096; // block bytes

/// A store with block-denominated tier budgets and no disk or watermark
/// machinery — admission outcomes are pure arithmetic.  Pinned capacity
/// doubles as migration staging, so tests that move bytes grant some.
fn store(gpu_blocks: u64, pinned_blocks: u64, dram_blocks: u64) -> KvStore {
    let link = LinkConfig::with_bandwidth(500e6);
    KvStore::new(
        KvStoreConfig {
            gpu_bytes: gpu_blocks * BB,
            pinned_bytes: pinned_blocks * BB,
            dram_bytes: dram_blocks * BB,
            disk_bytes: 0,
            block_tokens: BT,
            nvme_link: LinkConfig::nvme_below(&link),
            link,
            wire_elem_bytes: 4.0,
            promote_cooldown: 0,
            spill_cooldown: 0,
            spill_floor: 0.0,
            spill_watermark: 0.0,
            spill_max_per_step: 2,
            shared_host: None,
        },
        Box::new(Lru),
    )
}

/// 4 prompt blocks' worth of identical bytes (the shared preamble).
fn preamble() -> Vec<u8> {
    b"sys: shared retrieval preamble ".iter().copied().cycle().take(4 * BT).collect()
}

#[test]
fn sharing_admits_strictly_more_sequences_at_the_same_budget() {
    // 12 dram blocks; every request wants 5 blocks over the same 4-block
    // preamble.  Private: ⌊12 / 5⌋ = 2 fit.  Shared: the first request
    // pays 5 (4 registered + 1 private), each later one adopts 4 and pays
    // 1 — so 1 + (12 − 5) = 8 fit.
    let prompt = preamble();
    let mut private = store(0, 0, 12);
    let fit_private =
        (0..10).filter(|&seq| private.admit(seq, 5 * BB, 5).is_ok()).count();
    assert_eq!(fit_private, 2);

    let mut shared = store(0, 0, 12);
    shared.enable_prefix_sharing();
    let fit_shared =
        (0..10).filter(|&seq| shared.admit_shared(seq, 5 * BB, 5, &prompt).is_ok()).count();
    assert_eq!(fit_shared, 8, "1 × 5 + 7 × 1 = 12 blocks");
    assert!(
        fit_shared > fit_private,
        "sharing must admit strictly more: {fit_shared} vs {fit_private}"
    );
    let st = shared.share_stats();
    assert_eq!(st.registered, 4, "the first sharer registers the preamble chain");
    assert_eq!(st.adoptions, 7 * 4, "every later sharer adopts all 4 blocks");
}

#[test]
fn sharing_launches_strictly_fewer_wire_bytes_at_the_same_budget() {
    // Two sequences over the same preamble, fully decoded, then promoted
    // into an ample gpu tier.  Private: all 5 blocks of each sequence ride
    // the wire.  Shared: registry-owned marker blocks never migrate — the
    // planner already prices them at zero transfer — so only the private
    // tail block of each sequence does.
    let prompt = preamble();
    let drive = |s: &mut KvStore| {
        for seq in 0..2u64 {
            s.touch(seq, 5 * BT, 0);
            s.begin_promotions(seq, 5, MigrationClass::Promote);
        }
        s.pump_migrations(u64::MAX);
        s.migration_stats().wire_bytes
    };

    let mut private = store(16, 32, 16);
    for seq in 0..2 {
        private.admit(seq, 5 * BB, 5).unwrap();
    }
    let wire_private = drive(&mut private);

    let mut shared = store(16, 32, 16);
    shared.enable_prefix_sharing();
    for seq in 0..2 {
        shared.admit_shared(seq, 5 * BB, 5, &prompt).unwrap();
    }
    let wire_shared = drive(&mut shared);

    assert!(wire_shared > 0, "private tail blocks must still promote");
    assert!(
        wire_shared < wire_private,
        "sharing must launch strictly fewer wire bytes: {wire_shared} vs {wire_private}"
    );
}

fn artifacts() -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        dir
    } else {
        PathBuf::from("artifacts") // synthetic-manifest interpreter fallback
    }
}

fn interpreted() -> bool {
    !PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json").exists()
}

#[test]
fn dropped_kv_truncation_reclaims_host_bytes_and_keeps_decode_exact() {
    // Satellite regression: physically truncating a dropped prefix must
    // free exactly the host bytes it reports, raise the mandatory floor,
    // and — because build_step covers the hole with a real recompute
    // bucket — never change a generated token.
    let mut cfg = EngineConfig::new(EnginePolicy::Kvpr);
    cfg.link = LinkConfig::with_bandwidth(500e6);
    cfg.seed = 77;
    let engine = Engine::new(&artifacts(), cfg).unwrap();
    let tok = kvpr::model::ByteTokenizer::new();
    let prompts = vec![tok.encode("shared preamble reclamation", 16)];
    const GEN: usize = 30;

    let mut base = engine.start_batch(&prompts).unwrap();
    for _ in 1..GEN {
        engine.decode_step(&mut base).unwrap();
    }
    let base = engine.finish_batch(base);

    let mut sess = engine.start_batch(&prompts).unwrap();
    for step in 1..GEN {
        if step == 20 {
            // kv_len ≥ 35 by now: the 32-token L bucket covers the request
            let before = sess.host_bytes();
            let freed = engine.truncate_dropped_kv(&mut sess, 32);
            assert!(freed > 0, "truncation must free host K/V bytes");
            assert_eq!(
                sess.host_bytes(),
                before - freed,
                "reported bytes must match the physical shrink"
            );
            assert_eq!(sess.kv_floor(), 32, "the floor becomes mandatory");
            // re-truncating below the floor is a no-op
            assert_eq!(engine.truncate_dropped_kv(&mut sess, 16), 0);
        }
        engine.decode_step(&mut sess).unwrap();
    }
    let truncated = engine.finish_batch(sess);
    assert_eq!(
        base.tokens, truncated.tokens,
        "dropped-KV truncation changed generated tokens"
    );
}

#[test]
fn serving_with_sharing_adopts_prefixes_and_decodes_bit_identical() {
    // Four requests over one 32-byte-plus common prompt, one group each:
    // the first admission registers the preamble block, the next three
    // adopt it (ShareTotals hits), and flipping sharing off replays the
    // same workload to bit-identical tokens — the registry moves
    // reservations, never math.
    let mk = |sharing: bool| {
        let mut e = EngineConfig::new(EnginePolicy::Kvpr);
        e.weights_offloaded = true;
        e.link = LinkConfig::with_bandwidth(100e6);
        e.seed = 42;
        ContinuousConfig::builder("artifacts", e)
            .max_group(1)
            .max_groups(4)
            .admit_wait(Duration::from_millis(150))
            .prefix_sharing(sharing)
            .build()
    };
    let prompt = "the shared retrieval preamble anchors cross-request adoption";
    let run = |sharing: bool| {
        let server = ContinuousServer::start(mk(sharing)).unwrap();
        let handles: Vec<_> =
            (0..4).map(|_| server.dispatch((prompt, 6)).pop().unwrap()).collect();
        let mut tokens = Vec::new();
        for h in handles {
            let r = h.wait().unwrap();
            assert_eq!(r.tokens.len(), 6);
            tokens.push(r.tokens);
        }
        let share = server.metrics().share_totals();
        server.shutdown().unwrap();
        (tokens, share)
    };

    let (tok_on, share_on) = run(true);
    assert!(share_on.hits >= 1, "later admissions must adopt the registered prefix");
    assert!(share_on.tokens >= 32, "a full prompt block must be adopted");
    assert_eq!(share_on.blocks * 32, share_on.tokens, "blocks and tokens must agree");
    // every request decodes the same prompt: identical output per request
    for t in &tok_on[1..] {
        assert_eq!(t, &tok_on[0], "same prompt must decode identically");
    }

    let (tok_off, share_off) = run(false);
    assert_eq!(share_off, Default::default(), "sharing off records no hits");
    if interpreted() {
        assert_eq!(tok_on, tok_off, "prefix sharing changed generated tokens");
    }
}
