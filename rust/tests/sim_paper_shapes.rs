//! Paper-shape assertions over the simulator: who wins, by roughly what
//! factor, and where the crossovers fall — the reproduction contract of
//! DESIGN.md §6.

use kvpr::config::{HardwareConfig, ModelConfig, WorkloadConfig};
use kvpr::sim::{simulate_decode, Policy, RunConfig};

fn lat(model: ModelConfig, p: usize, g: usize, policy: Policy) -> f64 {
    simulate_decode(&RunConfig::new(
        model,
        HardwareConfig::a100_x16(),
        WorkloadConfig::latency_oriented(p, g),
        policy,
    ))
    .decode_s
}

fn thr(model: ModelConfig, hw: HardwareConfig, p: usize, g: usize, policy: Policy) -> f64 {
    simulate_decode(&RunConfig::new(
        model,
        hw,
        WorkloadConfig::throughput_oriented(p, g),
        policy,
    ))
    .tok_per_s
}

#[test]
fn fig7_latency_cut_in_paper_band() {
    // paper: up to 35.8% lower decode latency vs Accelerate
    for model in [ModelConfig::opt_6_7b(), ModelConfig::opt_13b()] {
        for (p, g) in [(128, 128), (512, 32)] {
            let acc = lat(model.clone(), p, g, Policy::Accelerate);
            let kv = lat(model.clone(), p, g, Policy::Kvpr);
            let cut = 1.0 - kv / acc;
            assert!(
                (0.05..0.45).contains(&cut),
                "{} {p}/{g}: cut {:.1}% outside the paper band",
                model.name,
                cut * 100.0
            );
        }
    }
}

#[test]
fn fig6_throughput_gain_in_paper_band() {
    // paper: up to 15.1% / 46.2% / 29.0% for OPT-6.7B/13B/30B
    let hw = HardwareConfig::a100_x16();
    for model in [ModelConfig::opt_6_7b(), ModelConfig::opt_13b(), ModelConfig::opt_30b()] {
        let flex = thr(model.clone(), hw.clone(), 1024, 32, Policy::FlexGen);
        let kvpr = thr(model.clone(), hw.clone(), 1024, 32, Policy::Kvpr);
        let gain = kvpr / flex - 1.0;
        assert!(
            (0.03..0.55).contains(&gain),
            "{}: gain {:.1}% outside band",
            model.name,
            gain * 100.0
        );
    }
}

#[test]
fn throughput_decreases_with_model_size() {
    let hw = HardwareConfig::a100_x16();
    let t67 = thr(ModelConfig::opt_6_7b(), hw.clone(), 512, 32, Policy::Kvpr);
    let t13 = thr(ModelConfig::opt_13b(), hw.clone(), 512, 32, Policy::Kvpr);
    let t30 = thr(ModelConfig::opt_30b(), hw, 512, 32, Policy::Kvpr);
    assert!(t67 > t13 && t13 > t30, "{t67} {t13} {t30}");
}

#[test]
fn longer_context_favours_kvpr_more() {
    // Fig 6: "as the KV cache grows larger, KVPR shows greater performance
    // benefits"
    let hw = HardwareConfig::a100_x16();
    let gain = |p| {
        let f = thr(ModelConfig::opt_13b(), hw.clone(), p, 32, Policy::FlexGen);
        let k = thr(ModelConfig::opt_13b(), hw.clone(), p, 32, Policy::Kvpr);
        k / f - 1.0
    };
    assert!(gain(1024) > gain(256), "{} vs {}", gain(1024), gain(256));
}

#[test]
fn table5_lowend_still_wins() {
    // paper: up to 15% on the RTX 5000 / x8 system
    let hw = HardwareConfig::rtx5000_x8();
    let flex = thr(ModelConfig::opt_6_7b(), hw.clone(), 1024, 32, Policy::FlexGen);
    let kvpr = thr(ModelConfig::opt_6_7b(), hw, 1024, 32, Policy::Kvpr);
    let gain = kvpr / flex - 1.0;
    assert!(gain > 0.02, "low-end gain {:.1}%", gain * 100.0);
}

#[test]
fn fig13_llama_shape() {
    // KVPR must beat both baselines on LLaMa2 geometries too
    for model in [ModelConfig::llama2_7b(), ModelConfig::llama2_13b()] {
        let acc = lat(model.clone(), 256, 32, Policy::Accelerate);
        let ds = lat(model.clone(), 256, 32, Policy::DeepSpeed);
        let kv = lat(model.clone(), 256, 32, Policy::Kvpr);
        assert!(kv < acc && kv < ds, "{}: {kv} vs {acc}/{ds}", model.name);
    }
}

#[test]
fn alisa_sits_between_flexgen_and_kvpr() {
    // sequential recompute-then-transfer saves volume but loses the overlap
    let hw = HardwareConfig::a100_x16();
    let model = ModelConfig::opt_6_7b();
    let flex = thr(model.clone(), hw.clone(), 1024, 16, Policy::FlexGen);
    let alisa = thr(model.clone(), hw.clone(), 1024, 16, Policy::AlisaLike);
    let kvpr = thr(model, hw, 1024, 16, Policy::Kvpr);
    assert!(kvpr > alisa, "kvpr {kvpr} vs alisa {alisa}");
    // ALISA transfers fewer bytes but serialises recompute before the
    // remainder transfer *and* loses the cross-layer link overlap, so it can
    // land below FlexGen — the point of the comparison is that the overlap
    // (KVPR's contribution over ALISA, paper §5) is what wins, not the
    // volume reduction alone.
    assert!(
        alisa > flex * 0.6,
        "alisa unreasonably slow: {alisa} vs flexgen {flex}"
    );
    assert!(
        kvpr / alisa > 1.15,
        "the overlap must be worth a clear margin: kvpr {kvpr} vs alisa {alisa}"
    );
}

#[test]
fn fig9_quant_gain_band() {
    let hw = HardwareConfig::a100_x16();
    let model = ModelConfig::opt_13b();
    let wl = WorkloadConfig::throughput_oriented(1024, 16);
    let plain = simulate_decode(&RunConfig::new(model.clone(), hw.clone(), wl.clone(), Policy::Kvpr));
    let mut wlq = wl;
    wlq.kv_quant_4bit = true;
    let quant = simulate_decode(&RunConfig::new(model, hw, wlq, Policy::Kvpr));
    let gain = quant.tok_per_s / plain.tok_per_s - 1.0;
    assert!(gain > 0.10, "quant gain {:.1}%", gain * 100.0);
}

#[test]
fn table2_hiding_never_loses_to_coarse_when_weight_bound() {
    // weight-bound regime: batch 1, weights offloaded
    let hw = HardwareConfig::a100_x16();
    let model = ModelConfig::opt_6_7b();
    let mut wl = WorkloadConfig::throughput_oriented(256, 16);
    wl.batch = 1;
    wl.n_batches = 1;
    let fine = simulate_decode(&RunConfig::new(model.clone(), hw.clone(), wl.clone(), Policy::Kvpr));
    let flex = simulate_decode(&RunConfig::new(model, hw, wl, Policy::FlexGen));
    // paper's claim: with hiding, KVPR is "no worse than the baseline"
    assert!(
        fine.decode_s <= flex.decode_s * 1.03,
        "hiding violated: kvpr {} vs flexgen {}",
        fine.decode_s,
        flex.decode_s
    );
}

#[test]
fn splits_respect_prompt_cap_and_grow() {
    let r = simulate_decode(&RunConfig::new(
        ModelConfig::opt_6_7b(),
        HardwareConfig::a100_x16(),
        WorkloadConfig::latency_oriented(128, 32),
        Policy::Kvpr,
    ));
    assert!(r.splits.iter().all(|&l| l <= 128));
    assert!(r.splits.windows(2).all(|w| w[1] >= w[0]));
}

#[test]
fn utilization_ordering_holds_across_hardware() {
    for hw in [HardwareConfig::a100_x16(), HardwareConfig::rtx5000_x8()] {
        let wl = WorkloadConfig::throughput_oriented(512, 8);
        let flex = simulate_decode(&RunConfig::new(
            ModelConfig::opt_6_7b(), hw.clone(), wl.clone(), Policy::FlexGen));
        let kvpr = simulate_decode(&RunConfig::new(
            ModelConfig::opt_6_7b(), hw.clone(), wl, Policy::Kvpr));
        assert!(kvpr.gpu_util > flex.gpu_util, "{}", hw.name);
    }
}
